//! Pluggable transport subsystem: the paper's one-ported, fully
//! bidirectional round exchange as a trait, with three interchangeable
//! backends.
//!
//! The schedules of the paper are computed *per processor* with no
//! communication, precisely so that they can drive real message-passing
//! systems. [`Transport`] captures the machine model those schedules
//! assume — per round a rank sends at most one block and receives at most
//! one block, send ∥ recv allowed — so that a single generic collective
//! (see [`crate::collectives::generic`]) runs unchanged over:
//!
//! * [`sim::SimTransport`] — lockstep rounds through the deterministic
//!   [`crate::simulator::Engine`]: machine-model enforcement plus
//!   cost-model accounting, the reference backend;
//! * [`thread::ThreadTransport`] — one OS thread per rank exchanging
//!   blocks over per-(sender, receiver) FIFO channels, real in-process
//!   parallelism;
//! * [`tcp::TcpTransport`] — sockets over localhost (or any reachable
//!   host set), each rank typically its own process, with a small
//!   length-prefixed wire format and a lazy, schedule-aware mesh.
//!
//! * [`cost::CostTransport`] — the same lockstep core tuned for
//!   cost-model *sweeps*: small per-rank stacks so `p` in the thousands is
//!   cheap, and first-class [`Payload::Virtual`] support so gigabyte
//!   messages are accounted without ever being materialized. This is the
//!   single execution path behind the paper's figure/table sweeps.
//!
//! On Unix hosts two more backends scale the point-to-point path out to
//! real multi-process runs: [`shm::ShmTransport`] — same-host ranks over
//! per-link SPSC ring buffers in one memmap'd segment, memory-speed
//! rounds across *processes* — and [`hier::HierTransport`] — the
//! composition that routes same-host peers over the segment and
//! cross-host peers over TCP. [`bootstrap`] is the rendezvous layer that
//! hands freshly-launched processes the rank→endpoint map, and the
//! `launch` CLI subcommand turns all of it into a one-command
//! multi-process demo.
//!
//! ## The zero-copy hot path
//!
//! The primitive is [`Transport::sendrecv_into`]: the outgoing payload is
//! *borrowed* ([`SendSpec::data`] is [`Payload::Bytes`] around a `&[u8]`,
//! so a sender never clones a block just to hand it to the transport) and
//! the incoming frame lands in a *caller-owned* `Vec<u8>` that is reused
//! round after round. After warm-up a steady-state round performs zero
//! payload heap allocations on the point-to-point backends; see DESIGN.md
//! §"Transport hot path". [`Transport::sendrecv`] remains as a convenience
//! shim that returns an owning [`WireMsg`] (allocating per call) for tests
//! and cold paths.
//!
//! ## Virtual payloads
//!
//! A payload is either real bytes or [`Payload::Virtual`]`(len)` — a
//! size-only block for cost-model sweeps that must never allocate
//! (`p = 1152`, gigabyte messages). The lockstep backends account virtual
//! bytes through the [`crate::simulator::CostModel`] exactly as they
//! would real ones and deliver a size-only frame (the receive buffer is
//! left empty); the point-to-point backends (thread, tcp) reject virtual
//! sends with a [`TransportError::Protocol`] — they exist to move real
//! bytes.
//!
//! The SPMD contract: every rank runs the same program and makes the same
//! sequence of [`Transport::sendrecv_into`] / [`Transport::barrier`]
//! calls, one per communication round. Point-to-point backends (thread,
//! tcp) only need per-pair FIFO ordering; the simulator backend
//! additionally uses the global round structure to enforce one-portedness
//! and to price each round at its maximum edge cost.

#![warn(missing_docs)]

pub mod bootstrap;
pub mod cost;
pub mod fault;
#[cfg(unix)]
pub mod hier;
pub mod recover;
#[cfg(unix)]
pub mod shm;
pub mod sim;
pub mod tcp;
pub mod thread;

use std::fmt;

/// One received block in the owning (shim) API: the sender's tag (block
/// index by convention of the collectives) plus the payload bytes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireMsg {
    /// Collective-defined tag (block index by convention).
    pub tag: u64,
    /// The payload bytes.
    pub data: Vec<u8>,
}

/// The payload of one outgoing block: real borrowed bytes, or a virtual
/// (size-only) block for cost-model sweeps that must not allocate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Payload<'a> {
    /// Real payload bytes, borrowed from the caller (may be empty —
    /// zero-sized blocks must still flow).
    Bytes(&'a [u8]),
    /// A size-only block of `len` bytes: accounted by the cost-model
    /// backends, never materialized. Rejected by the point-to-point
    /// backends, which exist to move real bytes.
    Virtual(u64),
}

impl Payload<'_> {
    /// Accounted size in bytes (the slice length for real payloads).
    #[inline]
    pub fn len(&self) -> u64 {
        match *self {
            Payload::Bytes(b) => b.len() as u64,
            Payload::Virtual(len) => len,
        }
    }

    /// Whether the accounted size is zero.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Whether this is a size-only (virtual) payload.
    #[inline]
    pub fn is_virtual(&self) -> bool {
        matches!(self, Payload::Virtual(_))
    }

    /// The real bytes, or `None` for a virtual payload.
    #[inline]
    pub fn bytes(&self) -> Option<&[u8]> {
        match *self {
            Payload::Bytes(b) => Some(b),
            Payload::Virtual(_) => None,
        }
    }
}

impl<'a> From<&'a [u8]> for Payload<'a> {
    fn from(b: &'a [u8]) -> Payload<'a> {
        Payload::Bytes(b)
    }
}

/// An outgoing block for one round. Real payloads are borrowed: transports
/// write them to the wire (the TCP backend as a single vectored write,
/// zero copies at any size) without taking ownership, so callers keep
/// their block storage across rounds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SendSpec<'a> {
    /// Destination rank.
    pub to: u64,
    /// Collective-defined tag (block index); verified by receivers.
    pub tag: u64,
    /// Payload: borrowed bytes or a virtual (size-only) block.
    pub data: Payload<'a>,
}

/// A backend's rough `α + β·bytes` link estimate, used by the algorithm
/// dispatch to derive its latency/bandwidth crossover instead of
/// hard-coding a byte constant (see
/// [`crate::collectives::generic::Algorithm`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostHint {
    /// Per-message startup latency in seconds.
    pub alpha_s: f64,
    /// Per-byte transfer time in seconds.
    pub beta_s_per_byte: f64,
}

impl CostHint {
    /// The fallback hint for backends without a calibrated model. Its
    /// [`CostHint::latency_cutoff_bytes`] is exactly the historical fixed
    /// 4096-byte cutoff
    /// ([`crate::collectives::generic::AUTO_LATENCY_CUTOFF`]), so the
    /// `Auto` heuristic behaves as before wherever no better estimate
    /// exists.
    pub const DEFAULT: CostHint = CostHint {
        alpha_s: 2.0e-6,
        beta_s_per_byte: 2.0e-6 / 4096.0,
    };

    /// The α/β of a [`crate::simulator::CostModel`] — for the hierarchical
    /// model, the inter-node link (the conservative choice: it is the one
    /// the `⌈log₂p⌉`-spanning schedules cannot avoid).
    pub fn from_model(model: &crate::simulator::CostModel) -> CostHint {
        match *model {
            crate::simulator::CostModel::Flat { alpha, beta } => CostHint {
                alpha_s: alpha,
                beta_s_per_byte: beta,
            },
            crate::simulator::CostModel::Hierarchical {
                inter_alpha,
                inter_beta,
                ..
            } => CostHint {
                alpha_s: inter_alpha,
                beta_s_per_byte: inter_beta,
            },
        }
    }

    /// The message size below which a collective is latency-bound: the
    /// size `α/β` at which per-message startup equals transfer time, so
    /// below it a `⌈log₂p⌉`-round whole-message algorithm beats a
    /// pipelined one. Clamped to `[1, 2³⁰]` (a β-free model would push the
    /// cutoff to infinity, which would disable pipelining everywhere).
    pub fn latency_cutoff_bytes(&self) -> u64 {
        if self.alpha_s <= 0.0 {
            return 1; // latency-free links: always pipeline
        }
        if self.beta_s_per_byte <= 0.0 {
            return 1 << 30; // bandwidth-free links: always latency-bound
        }
        let cutoff = (self.alpha_s / self.beta_s_per_byte).round();
        (cutoff.clamp(1.0, (1u64 << 30) as f64) as u64).max(1)
    }
}

impl Default for CostHint {
    fn default() -> CostHint {
        CostHint::DEFAULT
    }
}

/// A free-list of `Vec<u8>` recycled across rounds: `get` pops a warm
/// buffer (or allocates once, cold), `put` clears and shelves it. Both the
/// transports (frame-assembly and channel buffers) and the generic
/// collectives (block storage) use one per rank, which is what makes
/// steady-state rounds allocation-free.
#[derive(Debug)]
pub struct BufferPool {
    free: Vec<Vec<u8>>,
    max: usize,
}

impl BufferPool {
    /// A pool that shelves at most `max` free buffers (beyond that, `put`
    /// drops them). Note the cap bounds the *count*, not bytes: shelved
    /// buffers keep their capacity, so a pool that served huge blocks
    /// retains up to `max` huge allocations until dropped — size `max` to
    /// the working set (collectives need ~n + 1 buffers in flight).
    pub fn with_capacity(max: usize) -> BufferPool {
        BufferPool {
            free: Vec::new(),
            max,
        }
    }

    /// A warm buffer if one is shelved, else a fresh empty one. Always
    /// returned cleared.
    pub fn get(&mut self) -> Vec<u8> {
        match self.free.pop() {
            Some(buf) => {
                crate::obs::metrics::on_pool_hit();
                buf
            }
            None => {
                crate::obs::metrics::on_pool_miss();
                Vec::new()
            }
        }
    }

    /// Recycle a buffer (cleared, capacity kept) for a later `get`.
    pub fn put(&mut self, mut buf: Vec<u8>) {
        if self.free.len() < self.max {
            buf.clear();
            self.free.push(buf);
        }
    }

    /// Number of buffers currently shelved.
    pub fn shelved(&self) -> usize {
        self.free.len()
    }
}

impl Default for BufferPool {
    fn default() -> Self {
        BufferPool::with_capacity(256)
    }
}

/// Structured failure context attached to the point-to-point transport
/// errors: which peer the failing operation involved, the transport-level
/// communication round (a per-endpoint `sendrecv_into` counter — barrier
/// token exchanges included, so it is an operation index, not the
/// collective's external round number), and the collective epoch (advanced
/// by [`tcp::TcpTransport::reap_idle`]; backends without epochs leave it
/// `None`).
///
/// Every field is optional: errors raised before a peer is known (listener
/// setup, spawn failures) carry an empty context, which [`fmt::Display`]
/// omits entirely.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultCtx {
    /// The peer rank the failing send/recv/dial involved.
    pub peer: Option<u64>,
    /// Transport-level round (operation) counter at the failure.
    pub round: Option<u64>,
    /// Collective epoch at the failure (TCP backend only).
    pub epoch: Option<u64>,
}

impl FaultCtx {
    /// A context naming just the peer.
    pub fn peer(peer: u64) -> FaultCtx {
        FaultCtx {
            peer: Some(peer),
            ..FaultCtx::default()
        }
    }

    /// Attach the transport-level round counter.
    pub fn with_round(mut self, round: u64) -> FaultCtx {
        self.round = Some(round);
        self
    }

    /// Attach the collective epoch.
    pub fn with_epoch(mut self, epoch: u64) -> FaultCtx {
        self.epoch = Some(epoch);
        self
    }

    /// Whether no field is set.
    pub fn is_empty(&self) -> bool {
        self.peer.is_none() && self.round.is_none() && self.epoch.is_none()
    }
}

impl fmt::Display for FaultCtx {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut sep = "";
        write!(f, "[")?;
        if let Some(p) = self.peer {
            write!(f, "peer={p}")?;
            sep = " ";
        }
        if let Some(r) = self.round {
            write!(f, "{sep}round={r}")?;
            sep = " ";
        }
        if let Some(e) = self.epoch {
            write!(f, "{sep}epoch={e}")?;
        }
        write!(f, "]")
    }
}

/// Failures raised by a transport backend or by the collective layer on
/// top of it.
///
/// The point-to-point failure variants ([`TransportError::Io`],
/// [`TransportError::Timeout`], [`TransportError::Fault`]) carry a
/// structured [`FaultCtx`] naming the peer rank, the transport round and
/// the collective epoch, so a dead rank surfaces as *which* peer failed to
/// deliver in *which* round instead of a bare string. The enum is
/// `#[non_exhaustive]`: downstream matches must carry a wildcard arm, so
/// new failure classes can be added without a breaking change.
#[derive(Debug)]
#[non_exhaustive]
pub enum TransportError {
    /// Machine-model violation reported by the simulator backend.
    Sim(crate::simulator::SimError),
    /// Socket / channel failure.
    Io {
        /// Human-readable description.
        msg: String,
        /// Peer/round/epoch context (empty when unknown).
        ctx: FaultCtx,
    },
    /// A peer spoke the wrong protocol (bad magic, wrong sender, a message
    /// where none was scheduled, ...).
    Protocol {
        /// Human-readable description.
        msg: String,
        /// Peer/round/epoch context (empty when unknown).
        ctx: FaultCtx,
    },
    /// Timed out waiting for a peer.
    Timeout {
        /// Human-readable description.
        msg: String,
        /// Peer/round/epoch context (empty when unknown).
        ctx: FaultCtx,
    },
    /// Collective-level violation (schedule mismatch, corrupt delivery).
    Collective(String),
    /// An injected fault fired on this endpoint (see
    /// [`fault::FaultTransport`]): the deterministic first cause of a
    /// failure scenario, as opposed to the [`TransportError::Timeout`] /
    /// [`TransportError::Io`] fallout other ranks observe.
    Fault {
        /// Human-readable description of the injected fault.
        msg: String,
        /// Peer/round/epoch context (empty when unknown).
        ctx: FaultCtx,
    },
}

impl TransportError {
    /// An [`TransportError::Io`] with no context.
    pub fn io(msg: impl Into<String>) -> TransportError {
        TransportError::Io {
            msg: msg.into(),
            ctx: FaultCtx::default(),
        }
    }

    /// An [`TransportError::Io`] with peer/round/epoch context.
    pub fn io_at(msg: impl Into<String>, ctx: FaultCtx) -> TransportError {
        TransportError::Io {
            msg: msg.into(),
            ctx,
        }
    }

    /// A [`TransportError::Protocol`] with no context.
    pub fn protocol(msg: impl Into<String>) -> TransportError {
        TransportError::Protocol {
            msg: msg.into(),
            ctx: FaultCtx::default(),
        }
    }

    /// A [`TransportError::Protocol`] with peer/round/epoch context.
    pub fn protocol_at(msg: impl Into<String>, ctx: FaultCtx) -> TransportError {
        TransportError::Protocol {
            msg: msg.into(),
            ctx,
        }
    }

    /// A [`TransportError::Timeout`] with no context.
    pub fn timeout(msg: impl Into<String>) -> TransportError {
        TransportError::Timeout {
            msg: msg.into(),
            ctx: FaultCtx::default(),
        }
    }

    /// A [`TransportError::Timeout`] with peer/round/epoch context.
    pub fn timeout_at(msg: impl Into<String>, ctx: FaultCtx) -> TransportError {
        TransportError::Timeout {
            msg: msg.into(),
            ctx,
        }
    }

    /// A [`TransportError::Fault`] with peer/round/epoch context.
    pub fn fault_at(msg: impl Into<String>, ctx: FaultCtx) -> TransportError {
        TransportError::Fault {
            msg: msg.into(),
            ctx,
        }
    }

    /// The structured context, if this variant carries one.
    pub fn ctx(&self) -> Option<FaultCtx> {
        match self {
            TransportError::Io { ctx, .. }
            | TransportError::Timeout { ctx, .. }
            | TransportError::Protocol { ctx, .. }
            | TransportError::Fault { ctx, .. } => Some(*ctx),
            _ => None,
        }
    }
}

impl fmt::Display for TransportError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let write_ctx = |f: &mut fmt::Formatter<'_>, ctx: &FaultCtx| {
            if ctx.is_empty() {
                Ok(())
            } else {
                write!(f, " {ctx}")
            }
        };
        match self {
            TransportError::Sim(e) => write!(f, "simulator: {e}"),
            TransportError::Io { msg, ctx } => {
                write!(f, "io: {msg}")?;
                write_ctx(f, ctx)
            }
            TransportError::Protocol { msg, ctx } => {
                write!(f, "protocol: {msg}")?;
                write_ctx(f, ctx)
            }
            TransportError::Timeout { msg, ctx } => {
                write!(f, "timeout: {msg}")?;
                write_ctx(f, ctx)
            }
            TransportError::Collective(msg) => write!(f, "collective: {msg}"),
            TransportError::Fault { msg, ctx } => {
                write!(f, "fault: {msg}")?;
                write_ctx(f, ctx)
            }
        }
    }
}

impl std::error::Error for TransportError {}

impl From<crate::simulator::SimError> for TransportError {
    fn from(e: crate::simulator::SimError) -> TransportError {
        TransportError::Sim(e)
    }
}

impl From<std::io::Error> for TransportError {
    fn from(e: std::io::Error) -> TransportError {
        TransportError::io(e.to_string())
    }
}

/// The paper's one-ported, fully bidirectional round exchange.
///
/// [`Transport::sendrecv_into`] is the single communication primitive: in
/// one round a rank optionally sends one block and optionally receives one
/// block, and the two directions overlap. `recv_from` names the expected
/// source — the schedules are deterministic, so every rank knows its
/// from-processor each round and no metadata is ever exchanged.
pub trait Transport {
    /// This endpoint's rank in `0..size()`.
    fn rank(&self) -> u64;

    /// Number of ranks `p`.
    fn size(&self) -> u64;

    /// Execute one communication round: send `send` (if any, payload
    /// borrowed) while receiving one block from `recv_from` (if any) into
    /// `recv_buf`.
    ///
    /// When a block is received, `recv_buf` is cleared and filled with
    /// exactly the payload (its capacity is reused across rounds — after
    /// warm-up no reallocation happens) and the sender's tag is returned.
    /// A received *virtual* block (cost-model backends only) clears
    /// `recv_buf` and returns the tag — size-only frames carry no bytes.
    /// When `recv_from` is `None`, `recv_buf` is left untouched and the
    /// result is `Ok(None)`.
    ///
    /// The borrowed `send.data` is fully consumed before the call returns
    /// — backends that hand the frame to helper machinery (the TCP
    /// backend's persistent writer thread) must uphold an
    /// *ack-before-return* invariant so the caller can immediately reuse
    /// or drop its block storage. Send ∥ recv overlap within the call:
    /// a full-duplex round whose payloads exceed any internal buffering
    /// must not deadlock.
    fn sendrecv_into(
        &mut self,
        send: Option<SendSpec<'_>>,
        recv_from: Option<u64>,
        recv_buf: &mut Vec<u8>,
    ) -> Result<Option<u64>, TransportError>;

    /// Owning convenience shim over [`Transport::sendrecv_into`]: the
    /// received block comes back as a fresh [`WireMsg`] (one allocation
    /// per received frame). Kept for tests, cold paths and callers that
    /// genuinely want ownership.
    fn sendrecv(
        &mut self,
        send: Option<SendSpec<'_>>,
        recv_from: Option<u64>,
    ) -> Result<Option<WireMsg>, TransportError> {
        let mut data = Vec::new();
        Ok(self
            .sendrecv_into(send, recv_from, &mut data)?
            .map(|tag| WireMsg { tag, data }))
    }

    /// Hint that the backend may pre-establish the resources (connections,
    /// threads) the circulant schedules will use, so first rounds do not
    /// pay setup latency. Default: no-op; the TCP backend pre-connects its
    /// `2⌈log₂p⌉` circulant neighbors.
    fn warm_up(&mut self) -> Result<(), TransportError> {
        Ok(())
    }

    /// Hint that the backend may pre-establish links to exactly `peers`
    /// (duplicates, the own rank and out-of-range entries are ignored) —
    /// the non-circulant counterpart of [`Transport::warm_up`], used by
    /// the baseline collectives whose neighborhoods (binomial tree, ring,
    /// Bruck offsets) the circulant warm-up would not cover.
    ///
    /// Like every connection-setup path this must be called *collectively*
    /// with symmetric peer sets: if rank `a` lists `b`, rank `b` must list
    /// `a`, or the lazy TCP mesh's accept side waits for a dial that never
    /// comes. Default: no-op.
    fn warm_peers(&mut self, _peers: &[u64]) -> Result<(), TransportError> {
        Ok(())
    }

    /// This backend's rough `α + β·bytes` link estimate, used by the
    /// algorithm dispatch to place the latency/bandwidth crossover.
    /// Default: [`CostHint::DEFAULT`], whose cutoff is the historical
    /// fixed 4096-byte constant; the cost-model backends derive it from
    /// their configured [`crate::simulator::CostModel`].
    fn cost_hint(&self) -> CostHint {
        CostHint::DEFAULT
    }

    /// Block until every rank has reached the barrier.
    fn barrier(&mut self) -> Result<(), TransportError>;

    /// Override this backend's [`Transport::cost_hint`] with measured
    /// constants — typically a [`crate::obs::calibrate::Fit`] from a
    /// recorded run — so `Algorithm::Auto` and the n* segmentation
    /// resolve against reality instead of the static default. Everything
    /// else forwards to the wrapped transport unchanged.
    fn with_measured_hint(self, hint: CostHint) -> MeasuredHint<Self>
    where
        Self: Sized,
    {
        MeasuredHint { inner: self, hint }
    }
}

/// A transport whose [`Transport::cost_hint`] is pinned to a measured
/// value; see [`Transport::with_measured_hint`].
#[derive(Debug)]
pub struct MeasuredHint<T> {
    inner: T,
    hint: CostHint,
}

impl<T> MeasuredHint<T> {
    /// The pinned hint.
    pub fn hint(&self) -> CostHint {
        self.hint
    }

    /// Unwrap back to the underlying transport.
    pub fn into_inner(self) -> T {
        self.inner
    }
}

/// Boxed transports are transports: delegation so trait objects compose
/// with the wrapper transports ([`MeasuredHint`],
/// [`fault::FaultTransport`]) — e.g. the CLI wraps the backend it
/// selected at runtime, `FaultTransport<Box<dyn Transport>>`.
impl<T: Transport + ?Sized> Transport for Box<T> {
    fn rank(&self) -> u64 {
        (**self).rank()
    }

    fn size(&self) -> u64 {
        (**self).size()
    }

    fn sendrecv_into(
        &mut self,
        send: Option<SendSpec<'_>>,
        recv_from: Option<u64>,
        recv_buf: &mut Vec<u8>,
    ) -> Result<Option<u64>, TransportError> {
        (**self).sendrecv_into(send, recv_from, recv_buf)
    }

    fn warm_up(&mut self) -> Result<(), TransportError> {
        (**self).warm_up()
    }

    fn warm_peers(&mut self, peers: &[u64]) -> Result<(), TransportError> {
        (**self).warm_peers(peers)
    }

    fn cost_hint(&self) -> CostHint {
        (**self).cost_hint()
    }

    fn barrier(&mut self) -> Result<(), TransportError> {
        (**self).barrier()
    }
}

impl<T: Transport> Transport for MeasuredHint<T> {
    fn rank(&self) -> u64 {
        self.inner.rank()
    }

    fn size(&self) -> u64 {
        self.inner.size()
    }

    fn sendrecv_into(
        &mut self,
        send: Option<SendSpec<'_>>,
        recv_from: Option<u64>,
        recv_buf: &mut Vec<u8>,
    ) -> Result<Option<u64>, TransportError> {
        self.inner.sendrecv_into(send, recv_from, recv_buf)
    }

    fn warm_up(&mut self) -> Result<(), TransportError> {
        self.inner.warm_up()
    }

    fn warm_peers(&mut self, peers: &[u64]) -> Result<(), TransportError> {
        self.inner.warm_peers(peers)
    }

    fn cost_hint(&self) -> CostHint {
        self.hint
    }

    fn barrier(&mut self) -> Result<(), TransportError> {
        self.inner.barrier()
    }
}

/// Shared tail of the SPMD harnesses (`sim::run_sim`, `thread::run_threads`,
/// `tcp::run_tcp`): collect per-rank results, preferring the first
/// *substantive* error over secondary fallout (timeouts, hangups, abort
/// notifications) that another rank's failure caused.
fn drain_results<R>(
    results: Vec<Option<Result<R, TransportError>>>,
    is_secondary: impl Fn(&TransportError) -> bool,
) -> Result<Vec<R>, TransportError> {
    let mut out = Vec::with_capacity(results.len());
    let mut secondary: Option<TransportError> = None;
    for res in results {
        match res.expect("every rank joined") {
            Ok(v) => out.push(v),
            Err(e) => {
                if is_secondary(&e) {
                    if secondary.is_none() {
                        secondary = Some(e);
                    }
                } else {
                    return Err(e);
                }
            }
        }
    }
    if let Some(e) = secondary {
        return Err(e);
    }
    Ok(out)
}

/// Dissemination barrier over the reserved tag `u64::MAX`:
/// `⌈log₂p⌉` token exchanges, each rank sending to `rank + 2ᵏ` while
/// receiving from `rank - 2ᵏ`. Per-pair FIFO keeps tokens behind any
/// in-flight data; all blocking is bounded by the backend's timeouts, so
/// one failed rank reports instead of hanging the rest. Shared by the
/// point-to-point backends' `barrier` impls (the lockstep simulator
/// synchronizes with an empty global round instead).
pub fn dissemination_barrier<T: Transport + ?Sized>(t: &mut T) -> Result<(), TransportError> {
    const BARRIER_TAG: u64 = u64::MAX;
    let p = t.size();
    if p == 1 {
        return Ok(());
    }
    let rank = t.rank();
    let q = crate::sched::ceil_log2(p);
    let mut token = Vec::new();
    for k in 0..q {
        let step = 1u64 << k;
        let to = (rank + step) % p;
        let from = (rank + p - step) % p;
        let got = t.sendrecv_into(
            Some(SendSpec {
                to,
                tag: BARRIER_TAG,
                data: Payload::Bytes(&[]),
            }),
            Some(from),
            &mut token,
        )?;
        match got {
            Some(BARRIER_TAG) if token.is_empty() => {}
            Some(tag) => {
                return Err(TransportError::protocol(format!(
                    "rank {rank}: expected barrier token from {from}, got block {tag}"
                )))
            }
            None => unreachable!("recv_from was Some"),
        }
    }
    Ok(())
}

/// A round in which this rank neither sends nor receives. On the lockstep
/// simulator backend the rank still participates in the global round; on
/// point-to-point backends this is a no-op.
pub fn idle_round<T: Transport + ?Sized>(t: &mut T) -> Result<(), TransportError> {
    let mut scratch = Vec::new();
    match t.sendrecv_into(None, None, &mut scratch)? {
        None => Ok(()),
        Some(tag) => Err(TransportError::protocol(format!(
            "rank {}: received block {tag} in an idle round",
            t.rank()
        ))),
    }
}

/// Reserved tag for warm-up probe rounds (`u64::MAX` is the barrier
/// token; collective tags are block indices, far below both).
pub(crate) const PROBE_TAG: u64 = u64::MAX - 1;

/// Reserved tag for the membership-agreement gossip frames of
/// [`recover::agree_failures`] (below the barrier token and the warm-up
/// probe; collective tags are block indices, far below all three).
pub(crate) const GOSSIP_TAG: u64 = u64::MAX - 2;

/// Downgrade a warm-up failure to a logged warning. Warm-up is an
/// optimization — pre-established links and a measured α/β fit — so a
/// timed-out or faulted probe must not kill a run that can still complete
/// over lazily-established links with the static cost hint. Every
/// backend's `warm_up` routes its internal failures through here instead
/// of propagating them (pinned by the sever-plan warm-up test in
/// `rust/tests/faults.rs`).
pub(crate) fn warn_warm_up(rank: u64, what: &str, e: &TransportError) {
    eprintln!(
        "[warn] rank {rank}: warm-up {what} failed ({e}); \
         continuing with lazy links and the static cost hint"
    );
}

/// One symmetric probe round: send `bytes` to the next ring neighbor,
/// receive the same-sized block from the previous one.
fn probe_round<T: Transport + ?Sized>(
    t: &mut T,
    bytes: &[u8],
    buf: &mut Vec<u8>,
) -> Result<(), TransportError> {
    let (rank, p) = (t.rank(), t.size());
    let got = t.sendrecv_into(
        Some(SendSpec {
            to: (rank + 1) % p,
            tag: PROBE_TAG,
            data: Payload::Bytes(bytes),
        }),
        Some((rank + p - 1) % p),
        buf,
    )?;
    if got != Some(PROBE_TAG) || buf.len() != bytes.len() {
        return Err(TransportError::protocol(format!(
            "rank {rank}: warm-up probe expected a {}-byte PROBE block, got tag {got:?} ({} bytes)",
            bytes.len(),
            buf.len()
        )));
    }
    Ok(())
}

/// The warm-up α/β probe: measure this backend's per-message latency and
/// per-byte cost from timed ring exchanges, then agree on one value.
///
/// **Collective** — every rank must call it at the same point (the
/// point-to-point backends run it inside [`Transport::warm_up`]). Two
/// payload sizes (16 B and 64 KiB) are each exchanged along the ring
/// (`rank → rank+1`, both in the circulant warm set since `skip₀ = 1`),
/// one untimed sync round plus eight timed rounds; the two-point fit
/// gives a local `(α, β)`. A dissemination pass (componentwise **max**
/// over `⌈log₂p⌉` exchanges — idempotent, so the pattern yields the
/// identical combined value on every rank for any `p`) then replaces the
/// local fit: collectives resolve [`CostHint`]-driven decisions
/// (`Algorithm::Auto`, n* segmentation) identically on every rank, and
/// max is the conservative choice — the slowest link governs.
///
/// Returns `Ok(None)` (keep the static fallback) for `p < 2` or when the
/// agreed fit is degenerate (non-finite or non-positive) — the check runs
/// on the *consensus* value, so all ranks fall back together.
pub(crate) fn measure_link_hint<T: Transport + ?Sized>(
    t: &mut T,
) -> Result<Option<CostHint>, TransportError> {
    const SMALL: usize = 16;
    const LARGE: usize = 65536;
    const REPS: u32 = 8;
    let p = t.size();
    if p < 2 {
        return Ok(None);
    }
    let rank = t.rank();
    let payload = vec![0u8; LARGE];
    let mut buf = Vec::with_capacity(LARGE);
    let mut per_round = [0.0f64; 2];
    for (slot, size) in [SMALL, LARGE].into_iter().enumerate() {
        // One untimed round lines all ranks up so the timed window
        // measures the link, not arrival skew.
        probe_round(t, &payload[..size], &mut buf)?;
        let t0 = std::time::Instant::now();
        for _ in 0..REPS {
            probe_round(t, &payload[..size], &mut buf)?;
        }
        per_round[slot] = t0.elapsed().as_secs_f64() / f64::from(REPS);
    }
    let mut beta = (per_round[1] - per_round[0]) / (LARGE - SMALL) as f64;
    let mut alpha = per_round[0] - beta * SMALL as f64;
    let q = crate::sched::ceil_log2(p);
    let mut msg = [0u8; 16];
    for k in 0..q {
        let step = 1u64 << k;
        msg[..8].copy_from_slice(&alpha.to_le_bytes());
        msg[8..].copy_from_slice(&beta.to_le_bytes());
        let got = t.sendrecv_into(
            Some(SendSpec {
                to: (rank + step) % p,
                tag: PROBE_TAG,
                data: Payload::Bytes(&msg),
            }),
            Some((rank + p - step) % p),
            &mut buf,
        )?;
        if got != Some(PROBE_TAG) || buf.len() != 16 {
            return Err(TransportError::protocol(format!(
                "rank {rank}: probe consensus expected a 16-byte PROBE block, got tag {got:?} ({} bytes)",
                buf.len()
            )));
        }
        alpha = alpha.max(f64::from_le_bytes(buf[..8].try_into().expect("8 bytes")));
        beta = beta.max(f64::from_le_bytes(buf[8..].try_into().expect("8 bytes")));
    }
    if alpha.is_finite() && beta.is_finite() && alpha > 0.0 && beta > 0.0 {
        Ok(Some(CostHint {
            alpha_s: alpha,
            beta_s_per_byte: beta,
        }))
    } else {
        Ok(None)
    }
}

/// A sub-group view over any transport: group-relative rank `i` maps to
/// parent rank `members[i]`.
///
/// This is how the hierarchical collectives reuse the flat generic
/// collectives verbatim — e.g. the inter-node phase runs the ordinary
/// n-block broadcast over a [`GroupTransport`] whose members are the node
/// leaders, while non-members execute matching [`idle_round`]s (the round
/// counts are deterministic, so every rank knows how many).
pub struct GroupTransport<'a, T: Transport + ?Sized> {
    inner: &'a mut T,
    members: &'a [u64],
    index: u64,
}

impl<'a, T: Transport + ?Sized> GroupTransport<'a, T> {
    /// View `inner` as a `members.len()`-rank transport. The calling rank
    /// must be a member.
    pub fn new(
        inner: &'a mut T,
        members: &'a [u64],
    ) -> Result<GroupTransport<'a, T>, TransportError> {
        let me = inner.rank();
        let p = inner.size();
        if members.iter().any(|&m| m >= p) {
            return Err(TransportError::Collective(format!(
                "group member out of range (p = {p}): {members:?}"
            )));
        }
        let index = members
            .iter()
            .position(|&m| m == me)
            .ok_or_else(|| {
                TransportError::Collective(format!("rank {me} is not in group {members:?}"))
            })? as u64;
        Ok(GroupTransport {
            inner,
            members,
            index,
        })
    }

    fn resolve(&self, group_rank: u64) -> Result<u64, TransportError> {
        self.members.get(group_rank as usize).copied().ok_or_else(|| {
            TransportError::Collective(format!(
                "group rank {group_rank} out of range (group size {})",
                self.members.len()
            ))
        })
    }
}

impl<T: Transport + ?Sized> Transport for GroupTransport<'_, T> {
    fn rank(&self) -> u64 {
        self.index
    }

    fn size(&self) -> u64 {
        self.members.len() as u64
    }

    fn sendrecv_into(
        &mut self,
        send: Option<SendSpec<'_>>,
        recv_from: Option<u64>,
        recv_buf: &mut Vec<u8>,
    ) -> Result<Option<u64>, TransportError> {
        let send = match send {
            Some(s) => Some(SendSpec {
                to: self.resolve(s.to)?,
                tag: s.tag,
                data: s.data,
            }),
            None => None,
        };
        let recv_from = match recv_from {
            Some(f) => Some(self.resolve(f)?),
            None => None,
        };
        self.inner.sendrecv_into(send, recv_from, recv_buf)
    }

    // `warm_up` keeps the trait's no-op default on purpose: the group's
    // circulant neighborhood is *not* the parent transport's, so blanket
    // warming would dial links the group schedule never uses.

    fn cost_hint(&self) -> CostHint {
        self.inner.cost_hint()
    }

    fn warm_peers(&mut self, peers: &[u64]) -> Result<(), TransportError> {
        // Per the trait contract, out-of-range entries are ignored (not
        // errors): resolve what maps into the group, drop the rest.
        let resolved: Vec<u64> = peers
            .iter()
            .filter_map(|&g| self.members.get(g as usize).copied())
            .collect();
        self.inner.warm_peers(&resolved)
    }

    fn barrier(&mut self) -> Result<(), TransportError> {
        // A group barrier would have to involve non-members on the lockstep
        // backend; the collectives never need one.
        Err(TransportError::protocol(
            "barrier is not supported on a GroupTransport".into(),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A loopback transport for unit-testing the group mapping: records
    /// the parent-rank arguments of the last sendrecv.
    struct Recorder {
        rank: u64,
        p: u64,
        last: Option<(Option<u64>, Option<u64>)>,
    }

    impl Transport for Recorder {
        fn rank(&self) -> u64 {
            self.rank
        }
        fn size(&self) -> u64 {
            self.p
        }
        fn sendrecv_into(
            &mut self,
            send: Option<SendSpec<'_>>,
            recv_from: Option<u64>,
            _recv_buf: &mut Vec<u8>,
        ) -> Result<Option<u64>, TransportError> {
            self.last = Some((send.map(|s| s.to), recv_from));
            Ok(None)
        }
        fn barrier(&mut self) -> Result<(), TransportError> {
            Ok(())
        }
    }

    #[test]
    fn group_maps_ranks_through_members() {
        let mut base = Recorder {
            rank: 6,
            p: 8,
            last: None,
        };
        let members = [2u64, 6, 7];
        let mut g = GroupTransport::new(&mut base, &members).unwrap();
        assert_eq!(g.rank(), 1);
        assert_eq!(g.size(), 3);
        g.sendrecv(
            Some(SendSpec {
                to: 0,
                tag: 9,
                data: Payload::Bytes(&[1]),
            }),
            Some(2),
        )
        .unwrap();
        assert_eq!(base.last, Some((Some(2), Some(7))));
    }

    #[test]
    fn group_rejects_non_member_and_bad_indices() {
        let mut base = Recorder {
            rank: 5,
            p: 8,
            last: None,
        };
        assert!(GroupTransport::new(&mut base, &[0, 1]).is_err());
        let members = [5u64, 0];
        let mut g = GroupTransport::new(&mut base, &members).unwrap();
        assert!(g.sendrecv(None, Some(9)).is_err());
    }

    #[test]
    fn cost_hint_cutoffs() {
        // The fallback hint reproduces the historical fixed constant.
        assert_eq!(CostHint::DEFAULT.latency_cutoff_bytes(), 4096);
        // A calibrated flat model derives its own crossover.
        let m = crate::simulator::CostModel::Flat {
            alpha: 1.0e-6,
            beta: 1.0e-9,
        };
        assert_eq!(CostHint::from_model(&m).latency_cutoff_bytes(), 1000);
        // The hierarchical model uses the inter-node link.
        let h = crate::simulator::CostModel::Hierarchical {
            ranks_per_node: 4,
            intra_alpha: 1.0e-9,
            intra_beta: 1.0e-12,
            inter_alpha: 2.0e-6,
            inter_beta: 1.0e-9,
        };
        assert_eq!(CostHint::from_model(&h).latency_cutoff_bytes(), 2000);
        // Degenerate models clamp instead of exploding.
        let a0 = CostHint {
            alpha_s: 0.0,
            beta_s_per_byte: 1.0,
        };
        assert_eq!(a0.latency_cutoff_bytes(), 1);
        let b0 = CostHint {
            alpha_s: 1.0,
            beta_s_per_byte: 0.0,
        };
        assert_eq!(b0.latency_cutoff_bytes(), 1 << 30);
    }

    #[test]
    fn measured_hint_overrides_cost_hint_only() {
        let base = Recorder {
            rank: 3,
            p: 8,
            last: None,
        };
        assert_eq!(base.cost_hint(), CostHint::DEFAULT);
        let measured = CostHint {
            alpha_s: 5.0e-6,
            beta_s_per_byte: 1.0e-9,
        };
        let mut t = base.with_measured_hint(measured);
        assert_eq!(t.cost_hint(), measured);
        assert_eq!(t.rank(), 3);
        assert_eq!(t.size(), 8);
        t.sendrecv(
            Some(SendSpec {
                to: 1,
                tag: 0,
                data: Payload::Bytes(&[7]),
            }),
            Some(2),
        )
        .unwrap();
        assert_eq!(t.into_inner().last, Some((Some(1), Some(2))));
    }

    #[test]
    fn payload_len_and_kind() {
        let real = Payload::Bytes(&[1, 2, 3]);
        assert_eq!(real.len(), 3);
        assert!(!real.is_virtual());
        assert_eq!(real.bytes(), Some(&[1u8, 2, 3][..]));
        let virt = Payload::Virtual(1 << 30);
        assert_eq!(virt.len(), 1 << 30);
        assert!(virt.is_virtual() && !virt.is_empty());
        assert_eq!(virt.bytes(), None);
        assert!(Payload::Bytes(&[]).is_empty());
    }

    #[test]
    fn buffer_pool_recycles_capacity() {
        let mut pool = BufferPool::with_capacity(2);
        let mut a = pool.get();
        a.extend_from_slice(&[1, 2, 3, 4]);
        let cap = a.capacity();
        let ptr = a.as_ptr();
        pool.put(a);
        assert_eq!(pool.shelved(), 1);
        let b = pool.get();
        assert!(b.is_empty());
        assert_eq!(b.capacity(), cap);
        assert_eq!(b.as_ptr(), ptr);
        // The cap bounds retention.
        pool.put(Vec::with_capacity(8));
        pool.put(Vec::with_capacity(8));
        pool.put(Vec::with_capacity(8));
        assert_eq!(pool.shelved(), 2);
    }
}
