//! Simulator-backed transport: lockstep rounds through the deterministic
//! [`Engine`].
//!
//! Every rank runs on its own OS thread, but communication is globally
//! round-synchronous: a round executes once all `p` endpoints have called
//! [`SimTransport::sendrecv`], at which point the collected messages go
//! through [`Engine::exchange`] — so the one-ported machine model is
//! *enforced* (multi-send/multi-recv/self-messages are errors, exactly as
//! in the centralized cost-model collectives) and every round is priced at
//! its maximum edge cost under the configured [`CostModel`].
//!
//! This is the reference backend of the transport subsystem: the
//! cross-backend tests compare thread/tcp deliveries byte-for-byte against
//! the buffers it produces, and [`run_sim`] returns the engine's
//! [`Stats`] so transport-generic runs still yield the simulated
//! time/round/byte accounting of the paper's figures.

use super::{SendSpec, Transport, TransportError};
use crate::simulator::{CostModel, Engine, Msg, SimError, Stats};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

struct Round {
    engine: Engine,
    /// Sends collected for the round being assembled.
    msgs: Vec<Msg>,
    /// Delivery slots of the last executed round (index = receiver rank).
    inbox: Vec<Option<Msg>>,
    /// Endpoints that have called into the round being assembled.
    submitted: u64,
    /// Bumped once per executed round; waiters key on it.
    generation: u64,
    /// Endpoints that have been dropped (normally all-at-once at program
    /// end; early departures fail later rounds instead of hanging them).
    departed: u64,
    /// Sticky first failure; every subsequent call observes it.
    error: Option<SimError>,
}

struct Shared {
    p: u64,
    round: Mutex<Round>,
    cv: Condvar,
}

fn lock(m: &Mutex<Round>) -> MutexGuard<'_, Round> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// One rank's endpoint of the lockstep simulator transport. Create a full
/// set with [`run_sim`].
pub struct SimTransport {
    rank: u64,
    shared: Arc<Shared>,
}

impl Transport for SimTransport {
    fn rank(&self) -> u64 {
        self.rank
    }

    fn size(&self) -> u64 {
        self.shared.p
    }

    fn sendrecv_into(
        &mut self,
        send: Option<SendSpec<'_>>,
        recv_from: Option<u64>,
        recv_buf: &mut Vec<u8>,
    ) -> Result<Option<u64>, TransportError> {
        let sh = &self.shared;
        let mut st = lock(&sh.round);
        if st.departed > 0 && st.error.is_none() {
            // A peer is gone for good; this round can never fill up.
            st.error = Some(SimError::Collective(
                "a rank exited before the collective completed".into(),
            ));
            sh.cv.notify_all();
        }
        if let Some(e) = &st.error {
            return Err(TransportError::Sim(e.clone()));
        }
        let gen = st.generation;
        if let Some(s) = send {
            // The lockstep engine needs owned payloads (they cross the
            // round boundary); the copy is part of the simulator's price,
            // not of the machine model.
            st.msgs.push(Msg {
                from: self.rank,
                to: s.to,
                bytes: s.data.len() as u64,
                tag: s.tag,
                data: Some(s.data.to_vec()),
            });
        }
        st.submitted += 1;
        if st.submitted == sh.p {
            // Last rank in: execute the round for everyone.
            let msgs = std::mem::take(&mut st.msgs);
            match st.engine.exchange(msgs) {
                Ok(inbox) => st.inbox = inbox,
                Err(e) => st.error = Some(e),
            }
            st.submitted = 0;
            st.generation = gen + 1;
            sh.cv.notify_all();
        } else {
            while st.generation == gen && st.error.is_none() {
                st = sh.cv.wait(st).unwrap_or_else(|e| e.into_inner());
            }
        }
        if let Some(e) = &st.error {
            return Err(TransportError::Sim(e.clone()));
        }
        let got = st.inbox[self.rank as usize].take();
        drop(st);
        match (got, recv_from) {
            (None, None) => Ok(None),
            (Some(msg), Some(from)) => {
                if msg.from != from {
                    return Err(TransportError::Protocol(format!(
                        "rank {}: scheduled receive from {from}, message came from {}",
                        self.rank, msg.from
                    )));
                }
                recv_buf.clear();
                if let Some(data) = &msg.data {
                    recv_buf.extend_from_slice(data);
                }
                Ok(Some(msg.tag))
            }
            (Some(msg), None) => Err(TransportError::Protocol(format!(
                "rank {}: unscheduled message from {} (block {})",
                self.rank, msg.from, msg.tag
            ))),
            (None, Some(from)) => Err(TransportError::Collective(format!(
                "rank {}: scheduled block from {from} never arrived",
                self.rank
            ))),
        }
    }

    fn barrier(&mut self) -> Result<(), TransportError> {
        // An empty exchange synchronizes all ranks; the engine does not
        // account empty rounds, so a barrier is free in simulated time.
        let mut scratch = Vec::new();
        match self.sendrecv_into(None, None, &mut scratch)? {
            None => Ok(()),
            Some(_) => unreachable!("sendrecv(None, None) validated the empty inbox"),
        }
    }
}

impl Drop for SimTransport {
    fn drop(&mut self) {
        // If this endpoint exits (error or panic) while peers are waiting
        // on a round it will never join, fail the round loudly instead of
        // letting them block forever. Under the SPMD contract a normal
        // exit never observes a pending round.
        let sh = &self.shared;
        let mut st = lock(&sh.round);
        st.departed += 1;
        if st.submitted > 0 && st.error.is_none() {
            st.error = Some(SimError::Collective(format!(
                "rank {} exited while a round was pending",
                self.rank
            )));
            st.submitted = 0;
            st.generation += 1;
            sh.cv.notify_all();
        }
    }
}

/// Run `f` as an SPMD program: one OS thread per rank, each with its own
/// [`SimTransport`] endpoint, all communicating through one [`Engine`]
/// under `cost`.
///
/// Returns the per-rank results (index = rank) and the engine's final
/// accounting. If any rank fails, the first substantive error is returned
/// (abort-notifications raised on other ranks by the failure are
/// suppressed in its favor).
pub fn run_sim<R, F>(p: u64, cost: CostModel, f: F) -> Result<(Vec<R>, Stats), TransportError>
where
    R: Send,
    F: Fn(SimTransport) -> Result<R, TransportError> + Sync,
{
    assert!(p >= 1, "need at least one rank");
    let shared = Arc::new(Shared {
        p,
        round: Mutex::new(Round {
            engine: Engine::new(p, cost),
            msgs: Vec::new(),
            inbox: (0..p).map(|_| None).collect(),
            submitted: 0,
            generation: 0,
            departed: 0,
            error: None,
        }),
        cv: Condvar::new(),
    });
    let mut results: Vec<Option<Result<R, TransportError>>> = (0..p).map(|_| None).collect();
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(p as usize);
        for rank in 0..p {
            let shared = Arc::clone(&shared);
            let f = &f;
            handles.push(s.spawn(move || f(SimTransport { rank, shared })));
        }
        for (rank, h) in handles.into_iter().enumerate() {
            results[rank] = Some(h.join().unwrap_or_else(|_| {
                Err(TransportError::Collective(format!("rank {rank} panicked")))
            }));
        }
    });
    let out = super::drain_results(results, is_abort_notification)?;
    let stats = lock(&shared.round).engine.stats();
    Ok((out, stats))
}

/// True for the secondary errors ranks observe when a *different* rank
/// aborted a pending round (see `Drop`).
fn is_abort_notification(e: &TransportError) -> bool {
    matches!(e, TransportError::Sim(SimError::Collective(msg))
        if msg.contains("exited while a round was pending")
            || msg.contains("exited before the collective completed"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lockstep_round_delivers_and_accounts() {
        // Ring shift: rank r sends to r+1, receives from r-1, three rounds.
        let p = 4u64;
        let (results, stats) = run_sim(p, CostModel::Flat { alpha: 1.0, beta: 0.0 }, |mut t| {
            let r = t.rank();
            let mut seen = Vec::new();
            for round in 0..3u64 {
                let got = t.sendrecv(
                    Some(SendSpec {
                        to: (r + 1) % p,
                        tag: round,
                        data: &[r as u8; 2],
                    }),
                    Some((r + p - 1) % p),
                )?;
                let msg = got.expect("scheduled receive");
                assert_eq!(msg.tag, round);
                seen.push(msg.data[0]);
            }
            t.barrier()?;
            Ok(seen)
        })
        .unwrap();
        for (r, seen) in results.iter().enumerate() {
            let prev = ((r as u64 + p - 1) % p) as u8;
            assert_eq!(seen, &vec![prev, prev, prev]);
        }
        assert_eq!(stats.rounds, 3);
        assert_eq!(stats.bytes_on_wire, 3 * p * 2);
        assert!((stats.time_s - 3.0).abs() < 1e-12);
    }

    #[test]
    fn machine_model_enforced_across_threads() {
        // Two ranks both send to rank 2 in the same round: MultiRecv.
        let err = run_sim(3, CostModel::Flat { alpha: 0.0, beta: 0.0 }, |mut t| {
            let r = t.rank();
            let send = if r < 2 {
                Some(SendSpec {
                    to: 2,
                    tag: 0,
                    data: &[],
                })
            } else {
                None
            };
            t.sendrecv(send, if r == 2 { Some(0) } else { None })?;
            Ok(())
        })
        .unwrap_err();
        assert!(matches!(err, TransportError::Sim(SimError::MultiRecv(2))), "{err}");
    }

    #[test]
    fn early_exit_does_not_hang_peers() {
        let err = run_sim(2, CostModel::Flat { alpha: 0.0, beta: 0.0 }, |mut t| {
            if t.rank() == 0 {
                // Rank 0 fails before joining the round rank 1 is in.
                return Err(TransportError::Collective("boom".into()));
            }
            t.sendrecv(None, Some(0))?;
            Ok(())
        })
        .unwrap_err();
        assert!(matches!(err, TransportError::Collective(ref m) if m == "boom"), "{err}");
    }
}
