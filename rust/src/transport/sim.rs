//! Simulator-backed transport: the data-mode face of the lockstep
//! cost-model core in [`super::cost`].
//!
//! Since the one-rank-local-core refactor there is exactly one lockstep
//! implementation: [`super::cost::CostTransport`] collects every rank's
//! [`super::Transport::sendrecv_into`] call, funnels the round through
//! [`crate::simulator::Engine::exchange_into`] — so the one-ported
//! machine model is *enforced* (multi-send/multi-recv/self-messages are
//! errors) and every round is priced at its maximum edge cost under the
//! configured [`CostModel`] — and delivers real payload bytes when they
//! are provided.
//!
//! [`SimTransport`] is that same backend under its historical name, and
//! [`run_sim`] the matching harness: the *reference* backend of the
//! transport subsystem, which the cross-backend tests compare thread/tcp
//! deliveries against byte-for-byte, returning the engine's [`Stats`] so
//! transport-generic runs still yield the simulated time/round/byte
//! accounting of the paper's figures. Cost-only sweeps use
//! [`super::cost::run_cost`] with virtual payloads instead — same core,
//! no bytes.

use super::TransportError;
use crate::simulator::{CostModel, Stats};

/// One rank's endpoint of the lockstep simulator transport — the
/// historical name of [`super::cost::CostTransport`], kept because it is
/// the reference backend the data-mode tests and docs speak about. Create
/// a full set with [`run_sim`].
pub type SimTransport = super::cost::CostTransport;

/// Run `f` as an SPMD program: one OS thread per rank, each with its own
/// [`SimTransport`] endpoint, all communicating through one
/// [`crate::simulator::Engine`] under `cost`.
///
/// Returns the per-rank results (index = rank) and the engine's final
/// accounting. If any rank fails, the first substantive error is returned
/// (abort-notifications raised on other ranks by the failure are
/// suppressed in its favor).
pub fn run_sim<R, F>(p: u64, cost: CostModel, f: F) -> Result<(Vec<R>, Stats), TransportError>
where
    R: Send,
    F: Fn(SimTransport) -> Result<R, TransportError> + Sync,
{
    super::cost::run_cost(p, cost, f)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::SimError;
    use crate::transport::{Payload, SendSpec, Transport};

    #[test]
    fn lockstep_round_delivers_and_accounts() {
        // Ring shift: rank r sends to r+1, receives from r-1, three rounds.
        let p = 4u64;
        let (results, stats) = run_sim(
            p,
            CostModel::Flat {
                alpha: 1.0,
                beta: 0.0,
            },
            |mut t| {
                let r = t.rank();
                let mut seen = Vec::new();
                for round in 0..3u64 {
                    let got = t.sendrecv(
                        Some(SendSpec {
                            to: (r + 1) % p,
                            tag: round,
                            data: Payload::Bytes(&[r as u8; 2]),
                        }),
                        Some((r + p - 1) % p),
                    )?;
                    let msg = got.expect("scheduled receive");
                    assert_eq!(msg.tag, round);
                    seen.push(msg.data[0]);
                }
                t.barrier()?;
                Ok(seen)
            },
        )
        .unwrap();
        for (r, seen) in results.iter().enumerate() {
            let prev = ((r as u64 + p - 1) % p) as u8;
            assert_eq!(seen, &vec![prev, prev, prev]);
        }
        assert_eq!(stats.rounds, 3);
        assert_eq!(stats.bytes_on_wire, 3 * p * 2);
        assert!((stats.time_s - 3.0).abs() < 1e-12);
    }

    #[test]
    fn machine_model_enforced_across_threads() {
        // Two ranks both send to rank 2 in the same round: MultiRecv.
        let err = run_sim(
            3,
            CostModel::Flat {
                alpha: 0.0,
                beta: 0.0,
            },
            |mut t| {
                let r = t.rank();
                let send = if r < 2 {
                    Some(SendSpec {
                        to: 2,
                        tag: 0,
                        data: Payload::Bytes(&[]),
                    })
                } else {
                    None
                };
                t.sendrecv(send, if r == 2 { Some(0) } else { None })?;
                Ok(())
            },
        )
        .unwrap_err();
        assert!(matches!(err, TransportError::Sim(SimError::MultiRecv(2))), "{err}");
    }

    #[test]
    fn early_exit_does_not_hang_peers() {
        let err = run_sim(
            2,
            CostModel::Flat {
                alpha: 0.0,
                beta: 0.0,
            },
            |mut t| {
                if t.rank() == 0 {
                    // Rank 0 fails before joining the round rank 1 is in.
                    return Err(TransportError::Collective("boom".into()));
                }
                t.sendrecv(None, Some(0))?;
                Ok(())
            },
        )
        .unwrap_err();
        assert!(matches!(err, TransportError::Collective(ref m) if m == "boom"), "{err}");
    }
}
