//! Thread-backed transport: one OS thread per rank, lock-free FIFO
//! channels per directed pair.
//!
//! This is real parallel execution inside one process: there is no global
//! round structure and no shared schedule state — each rank acts only on
//! its local `O(log p)` schedule, and messages pair up because the
//! schedules are correct (the paper's Condition 1). The per-(sender,
//! receiver) channels keep blocks FIFO per pair, which together with
//! schedule determinism makes the receive side unambiguous; block tags are
//! still asserted by the collective layer.
//!
//! A failing rank cannot hang the rest: receives time out (configurable)
//! and report which peer and block they were waiting for.

use super::{SendSpec, Transport, TransportError, WireMsg};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::Duration;

/// One rank's endpoint of the in-process channel mesh. Create a full set
/// with [`ThreadTransport::mesh`] or run an SPMD program directly with
/// [`run_threads`].
pub struct ThreadTransport {
    rank: u64,
    p: u64,
    /// `senders[to]`: channel into `to`'s inbox slot for this rank.
    senders: Vec<Sender<WireMsg>>,
    /// `receivers[from]`: this rank's inbox slot for messages from `from`.
    receivers: Vec<Receiver<WireMsg>>,
    timeout: Duration,
}

impl ThreadTransport {
    /// Build the full `p`-rank mesh; element `r` of the result is rank
    /// `r`'s endpoint. Receives block for at most `timeout`.
    pub fn mesh(p: u64, timeout: Duration) -> Vec<ThreadTransport> {
        assert!(p >= 1, "need at least one rank");
        let pu = p as usize;
        // rxs[to][from] receives what txs[to][from] sends.
        let mut txs: Vec<Vec<Sender<WireMsg>>> = Vec::with_capacity(pu);
        let mut rxs: Vec<Vec<Receiver<WireMsg>>> = Vec::with_capacity(pu);
        for _ in 0..pu {
            let (mut tv, mut rv) = (Vec::with_capacity(pu), Vec::with_capacity(pu));
            for _ in 0..pu {
                let (tx, rx) = channel::<WireMsg>();
                tv.push(tx);
                rv.push(rx);
            }
            txs.push(tv);
            rxs.push(rv);
        }
        // Transpose the senders: endpoint `from` needs txs[to][from] for
        // every `to`.
        let mut senders: Vec<Vec<Sender<WireMsg>>> = (0..pu).map(|_| Vec::new()).collect();
        for row in txs {
            for (from, tx) in row.into_iter().enumerate() {
                senders[from].push(tx); // senders[from][to], to-major pushes
            }
        }
        senders
            .into_iter()
            .zip(rxs)
            .enumerate()
            .map(|(rank, (senders, receivers))| ThreadTransport {
                rank: rank as u64,
                p,
                senders,
                receivers,
                timeout,
            })
            .collect()
    }
}

impl Transport for ThreadTransport {
    fn rank(&self) -> u64 {
        self.rank
    }

    fn size(&self) -> u64 {
        self.p
    }

    fn sendrecv(
        &mut self,
        send: Option<SendSpec>,
        recv_from: Option<u64>,
    ) -> Result<Option<WireMsg>, TransportError> {
        // Fire the (non-blocking, unbounded-channel) send, then block on
        // the receive: send ∥ recv.
        if let Some(s) = send {
            if s.to >= self.p || s.to == self.rank {
                return Err(TransportError::Collective(format!(
                    "rank {}: invalid send destination {} (p = {})",
                    self.rank, s.to, self.p
                )));
            }
            self.senders[s.to as usize]
                .send(WireMsg {
                    tag: s.tag,
                    data: s.data,
                })
                .map_err(|_| {
                    TransportError::Io(format!(
                        "rank {}: peer {} hung up",
                        self.rank, s.to
                    ))
                })?;
        }
        match recv_from {
            None => Ok(None),
            Some(from) => {
                if from >= self.p || from == self.rank {
                    return Err(TransportError::Collective(format!(
                        "rank {}: invalid receive source {from} (p = {})",
                        self.rank, self.p
                    )));
                }
                match self.receivers[from as usize].recv_timeout(self.timeout) {
                    Ok(msg) => Ok(Some(msg)),
                    Err(RecvTimeoutError::Timeout) => Err(TransportError::Timeout(format!(
                        "rank {}: waited {:?} for a block from {from}",
                        self.rank, self.timeout
                    ))),
                    Err(RecvTimeoutError::Disconnected) => Err(TransportError::Io(format!(
                        "rank {}: peer {from} disconnected",
                        self.rank
                    ))),
                }
            }
        }
    }

    fn barrier(&mut self) -> Result<(), TransportError> {
        // Dissemination barrier over a reserved tag, like the TCP backend:
        // bounded by the receive timeout, so one failed rank cannot hang
        // the rest (which a std::sync::Barrier would).
        const BARRIER_TAG: u64 = u64::MAX;
        let p = self.p;
        if p == 1 {
            return Ok(());
        }
        let q = crate::sched::ceil_log2(p);
        for k in 0..q {
            let step = 1u64 << k;
            let to = (self.rank + step) % p;
            let from = (self.rank + p - step) % p;
            let got = self.sendrecv(
                Some(SendSpec {
                    to,
                    tag: BARRIER_TAG,
                    data: Vec::new(),
                }),
                Some(from),
            )?;
            match got {
                Some(msg) if msg.tag == BARRIER_TAG && msg.data.is_empty() => {}
                Some(msg) => {
                    return Err(TransportError::Protocol(format!(
                        "rank {}: expected barrier token from {from}, got block {}",
                        self.rank, msg.tag
                    )))
                }
                None => unreachable!("recv_from was Some"),
            }
        }
        Ok(())
    }
}

/// Run `f` as an SPMD program: one OS thread per rank over a fresh channel
/// mesh. Returns the per-rank results (index = rank); if ranks fail, the
/// first substantive error is returned (timeouts that are mere fallout of
/// another rank's failure are suppressed in its favor).
pub fn run_threads<R, F>(p: u64, timeout: Duration, f: F) -> Result<Vec<R>, TransportError>
where
    R: Send,
    F: Fn(ThreadTransport) -> Result<R, TransportError> + Sync,
{
    let endpoints = ThreadTransport::mesh(p, timeout);
    let mut results: Vec<Option<Result<R, TransportError>>> = (0..p).map(|_| None).collect();
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(p as usize);
        for t in endpoints {
            let f = &f;
            handles.push(s.spawn(move || f(t)));
        }
        for (rank, h) in handles.into_iter().enumerate() {
            results[rank] = Some(h.join().unwrap_or_else(|_| {
                Err(TransportError::Collective(format!("rank {rank} panicked")))
            }));
        }
    });
    super::drain_results(results, |e| {
        matches!(e, TransportError::Timeout(_) | TransportError::Io(_))
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pairwise_exchange_is_full_duplex() {
        // Every rank sends to its partner and receives from it in the same
        // round — the "fully bidirectional" part of the machine model.
        let results = run_threads(4, Duration::from_secs(10), |mut t| {
            let partner = t.rank() ^ 1;
            let got = t.sendrecv(
                Some(SendSpec {
                    to: partner,
                    tag: t.rank(),
                    data: vec![t.rank() as u8],
                }),
                Some(partner),
            )?;
            let msg = got.expect("scheduled receive");
            t.barrier()?;
            Ok((msg.tag, msg.data))
        })
        .unwrap();
        for (r, (tag, data)) in results.iter().enumerate() {
            assert_eq!(*tag, r as u64 ^ 1);
            assert_eq!(data, &vec![(r as u64 ^ 1) as u8]);
        }
    }

    #[test]
    fn fifo_per_pair_keeps_blocks_ordered() {
        let results = run_threads(2, Duration::from_secs(10), |mut t| {
            let mut tags = Vec::new();
            if t.rank() == 0 {
                for tag in 0..5u64 {
                    t.sendrecv(
                        Some(SendSpec {
                            to: 1,
                            tag,
                            data: vec![tag as u8; 3],
                        }),
                        None,
                    )?;
                }
            } else {
                for _ in 0..5 {
                    let msg = t.sendrecv(None, Some(0))?.expect("scheduled receive");
                    tags.push(msg.tag);
                }
            }
            Ok(tags)
        })
        .unwrap();
        assert_eq!(results[1], vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn timeout_reports_instead_of_hanging() {
        let err = run_threads(2, Duration::from_millis(50), |mut t| {
            if t.rank() == 0 {
                // Never sends; rank 1's receive must time out.
                return Ok(());
            }
            t.sendrecv(None, Some(0))?;
            Ok(())
        })
        .unwrap_err();
        assert!(matches!(err, TransportError::Timeout(_) | TransportError::Io(_)), "{err}");
    }
}
