//! Thread-backed transport: one OS thread per rank, lock-free FIFO
//! channels per directed pair.
//!
//! This is real parallel execution inside one process: there is no global
//! round structure and no shared schedule state — each rank acts only on
//! its local `O(log p)` schedule, and messages pair up because the
//! schedules are correct (the paper's Condition 1). The per-(sender,
//! receiver) channels keep blocks FIFO per pair, which together with
//! schedule determinism makes the receive side unambiguous; block tags are
//! still asserted by the collective layer.
//!
//! ## Buffer recycling
//!
//! Messages cross threads as owned `Vec<u8>`s, but those vectors are never
//! allocated in steady state: alongside every data channel runs a
//! *recycle* channel in the opposite direction. A receiver copies the
//! payload into the caller's reusable buffer and hands the vector straight
//! back to its sender, which prefers a returned vector (then its local
//! [`BufferPool`]) over a fresh allocation. After warm-up a round is two
//! memcpys and zero heap allocations.
//!
//! A failing rank cannot hang the rest: receives time out (configurable)
//! and report which peer and block they were waiting for.

use super::{BufferPool, FaultCtx, Payload, SendSpec, Transport, TransportError, WireMsg};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::Duration;

/// One rank's endpoint of the in-process channel mesh. Create a full set
/// with [`ThreadTransport::mesh`] or run an SPMD program directly with
/// [`run_threads`].
pub struct ThreadTransport {
    rank: u64,
    p: u64,
    /// `senders[to]`: channel into `to`'s inbox slot for this rank.
    senders: Vec<Sender<WireMsg>>,
    /// `receivers[from]`: this rank's inbox slot for messages from `from`.
    receivers: Vec<Receiver<WireMsg>>,
    /// `give_back[from]`: returns drained payload vectors to `from`.
    give_back: Vec<Sender<Vec<u8>>>,
    /// `take_back[to]`: vectors this rank sent to `to`, coming home.
    take_back: Vec<Receiver<Vec<u8>>>,
    pool: BufferPool,
    timeout: Duration,
    /// Transport-level round counter: one per `sendrecv_into` call, so
    /// failure context can name the round a peer went silent in.
    ops: u64,
}

impl ThreadTransport {
    /// Build the full `p`-rank mesh; element `r` of the result is rank
    /// `r`'s endpoint. Receives block for at most `timeout`.
    pub fn mesh(p: u64, timeout: Duration) -> Vec<ThreadTransport> {
        assert!(p >= 1, "need at least one rank");
        let pu = p as usize;
        // Channel matrices, indexed [from][to] for the sending halves and
        // [to][from] for the receiving halves. Self-slots get real (but
        // forever-unused, since sendrecv rejects self-messages) channels
        // so that indexing stays branch-free; that is 4 spare channel
        // allocations per rank, once per mesh.
        let mut senders: Vec<Vec<Option<Sender<WireMsg>>>> =
            (0..pu).map(|_| (0..pu).map(|_| None).collect()).collect();
        let mut receivers: Vec<Vec<Option<Receiver<WireMsg>>>> =
            (0..pu).map(|_| (0..pu).map(|_| None).collect()).collect();
        let mut give_back: Vec<Vec<Option<Sender<Vec<u8>>>>> =
            (0..pu).map(|_| (0..pu).map(|_| None).collect()).collect();
        let mut take_back: Vec<Vec<Option<Receiver<Vec<u8>>>>> =
            (0..pu).map(|_| (0..pu).map(|_| None).collect()).collect();
        for from in 0..pu {
            for to in 0..pu {
                let (tx, rx) = channel::<WireMsg>();
                senders[from][to] = Some(tx);
                receivers[to][from] = Some(rx);
                // Recycle path runs opposite the data path: `to` gives
                // drained vectors back, `from` takes them.
                let (rtx, rrx) = channel::<Vec<u8>>();
                give_back[to][from] = Some(rtx);
                take_back[from][to] = Some(rrx);
            }
        }
        let mut endpoints = Vec::with_capacity(pu);
        for rank in 0..pu {
            endpoints.push(ThreadTransport {
                rank: rank as u64,
                p,
                senders: senders[rank]
                    .iter_mut()
                    .map(|s| s.take().expect("filled above"))
                    .collect(),
                receivers: receivers[rank]
                    .iter_mut()
                    .map(|r| r.take().expect("filled above"))
                    .collect(),
                give_back: give_back[rank]
                    .iter_mut()
                    .map(|s| s.take().expect("filled above"))
                    .collect(),
                take_back: take_back[rank]
                    .iter_mut()
                    .map(|r| r.take().expect("filled above"))
                    .collect(),
                pool: BufferPool::default(),
                timeout,
                ops: 0,
            });
        }
        endpoints
    }

    /// A vector to carry an outgoing payload to `to`: drain everything the
    /// recycle channel brought home into the pool (keeping circulation as
    /// deep as the send/return imbalance ever got), then reuse from the
    /// pool; only the cold path allocates.
    fn outgoing_buf(&mut self, to: usize) -> Vec<u8> {
        while let Ok(v) = self.take_back[to].try_recv() {
            self.pool.put(v);
        }
        self.pool.get()
    }
}

impl Transport for ThreadTransport {
    fn rank(&self) -> u64 {
        self.rank
    }

    fn size(&self) -> u64 {
        self.p
    }

    fn sendrecv_into(
        &mut self,
        send: Option<SendSpec<'_>>,
        recv_from: Option<u64>,
        recv_buf: &mut Vec<u8>,
    ) -> Result<Option<u64>, TransportError> {
        #[cfg(feature = "obs")]
        let t0 = crate::obs::now_ns();
        #[cfg(feature = "obs")]
        let sent_info = send.map(|s| (s.to, s.tag, s.data.len()));
        let res = self.round_impl(send, recv_from, recv_buf);
        #[cfg(feature = "obs")]
        if let Ok(got) = &res {
            if let Some((_, _, bytes)) = sent_info {
                crate::obs::metrics::on_send(bytes);
            }
            let recv_info =
                got.map(|tag| (recv_from.expect("got implies recv_from"), tag, recv_buf.len() as u64));
            if let Some((_, _, bytes)) = recv_info {
                crate::obs::metrics::on_recv(bytes);
            }
            crate::obs::record_round(sent_info, recv_info, t0);
        }
        res
    }

    fn barrier(&mut self) -> Result<(), TransportError> {
        // Bounded by the receive timeout, so one failed rank cannot hang
        // the rest (which a std::sync::Barrier would).
        super::dissemination_barrier(self)
    }
}

impl ThreadTransport {
    /// The uninstrumented round body behind [`Transport::sendrecv_into`].
    fn round_impl(
        &mut self,
        send: Option<SendSpec<'_>>,
        recv_from: Option<u64>,
        recv_buf: &mut Vec<u8>,
    ) -> Result<Option<u64>, TransportError> {
        // Fire the (non-blocking, unbounded-channel) send, then block on
        // the receive: send ∥ recv.
        let round = self.ops;
        self.ops += 1;
        if let Some(s) = send {
            if s.to >= self.p || s.to == self.rank {
                return Err(TransportError::Collective(format!(
                    "rank {}: invalid send destination {} (p = {})",
                    self.rank, s.to, self.p
                )));
            }
            let Payload::Bytes(data) = s.data else {
                // Size-only payloads belong to the cost-model backends;
                // this backend exists to move real bytes.
                return Err(TransportError::protocol(format!(
                    "rank {}: virtual payload ({} bytes) on the thread backend \
                     — use the sim/cost backend for size-only sweeps",
                    self.rank,
                    s.data.len()
                )));
            };
            let mut buf = self.outgoing_buf(s.to as usize);
            buf.extend_from_slice(data);
            self.senders[s.to as usize]
                .send(WireMsg {
                    tag: s.tag,
                    data: buf,
                })
                .map_err(|_| {
                    TransportError::io_at(
                        format!("rank {}: peer {} hung up", self.rank, s.to),
                        FaultCtx::peer(s.to).with_round(round),
                    )
                })?;
        }
        match recv_from {
            None => Ok(None),
            Some(from) => {
                if from >= self.p || from == self.rank {
                    return Err(TransportError::Collective(format!(
                        "rank {}: invalid receive source {from} (p = {})",
                        self.rank, self.p
                    )));
                }
                match self.receivers[from as usize].recv_timeout(self.timeout) {
                    Ok(msg) => {
                        recv_buf.clear();
                        recv_buf.extend_from_slice(&msg.data);
                        // Hand the vector home for reuse; if the peer is
                        // gone, shelve it locally instead.
                        if let Err(e) = self.give_back[from as usize].send(msg.data) {
                            self.pool.put(e.0);
                        }
                        Ok(Some(msg.tag))
                    }
                    Err(RecvTimeoutError::Timeout) => Err(TransportError::timeout_at(
                        format!(
                            "rank {}: waited {:?} for a block from {from}",
                            self.rank, self.timeout
                        ),
                        FaultCtx::peer(from).with_round(round),
                    )),
                    Err(RecvTimeoutError::Disconnected) => Err(TransportError::io_at(
                        format!("rank {}: peer {from} disconnected", self.rank),
                        FaultCtx::peer(from).with_round(round),
                    )),
                }
            }
        }
    }
}

/// Run `f` as an SPMD program: one OS thread per rank over a fresh channel
/// mesh. Returns the per-rank results (index = rank); if ranks fail, the
/// first substantive error is returned (timeouts that are mere fallout of
/// another rank's failure are suppressed in its favor).
pub fn run_threads<R, F>(p: u64, timeout: Duration, f: F) -> Result<Vec<R>, TransportError>
where
    R: Send,
    F: Fn(ThreadTransport) -> Result<R, TransportError> + Sync,
{
    let endpoints = ThreadTransport::mesh(p, timeout);
    let mut results: Vec<Option<Result<R, TransportError>>> = (0..p).map(|_| None).collect();
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(p as usize);
        for t in endpoints {
            let f = &f;
            handles.push(s.spawn(move || f(t)));
        }
        for (rank, h) in handles.into_iter().enumerate() {
            results[rank] = Some(h.join().unwrap_or_else(|_| {
                Err(TransportError::Collective(format!("rank {rank} panicked")))
            }));
        }
    });
    super::drain_results(results, |e| {
        matches!(
            e,
            TransportError::Timeout { .. } | TransportError::Io { .. }
        )
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pairwise_exchange_is_full_duplex() {
        // Every rank sends to its partner and receives from it in the same
        // round — the "fully bidirectional" part of the machine model.
        let results = run_threads(4, Duration::from_secs(10), |mut t| {
            let partner = t.rank() ^ 1;
            let payload = [t.rank() as u8];
            let got = t.sendrecv(
                Some(SendSpec {
                    to: partner,
                    tag: t.rank(),
                    data: Payload::Bytes(&payload),
                }),
                Some(partner),
            )?;
            let msg = got.expect("scheduled receive");
            t.barrier()?;
            Ok((msg.tag, msg.data))
        })
        .unwrap();
        for (r, (tag, data)) in results.iter().enumerate() {
            assert_eq!(*tag, r as u64 ^ 1);
            assert_eq!(data, &vec![(r as u64 ^ 1) as u8]);
        }
    }

    #[test]
    fn fifo_per_pair_keeps_blocks_ordered() {
        let results = run_threads(2, Duration::from_secs(10), |mut t| {
            let mut tags = Vec::new();
            if t.rank() == 0 {
                for tag in 0..5u64 {
                    t.sendrecv(
                        Some(SendSpec {
                            to: 1,
                            tag,
                            data: Payload::Bytes(&[tag as u8; 3]),
                        }),
                        None,
                    )?;
                }
            } else {
                for _ in 0..5 {
                    let msg = t.sendrecv(None, Some(0))?.expect("scheduled receive");
                    tags.push(msg.tag);
                }
            }
            Ok(tags)
        })
        .unwrap();
        assert_eq!(results[1], vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn timeout_reports_instead_of_hanging() {
        let err = run_threads(2, Duration::from_millis(50), |mut t| {
            if t.rank() == 0 {
                // Never sends; rank 1's receive must time out.
                return Ok(());
            }
            t.sendrecv(None, Some(0))?;
            Ok(())
        })
        .unwrap_err();
        assert!(
            matches!(
                err,
                TransportError::Timeout { .. } | TransportError::Io { .. }
            ),
            "{err}"
        );
    }

    #[test]
    fn recycled_buffers_flow_home() {
        // Rank 0 streams blocks to rank 1; after warm-up rank 0's sends
        // must reuse vectors returned by rank 1 (no allocation growth).
        let results = run_threads(2, Duration::from_secs(10), |mut t| {
            let payload = [7u8; 256];
            let mut recv_buf = Vec::new();
            if t.rank() == 0 {
                for tag in 0..50u64 {
                    t.sendrecv_into(
                        Some(SendSpec {
                            to: 1,
                            tag,
                            data: Payload::Bytes(&payload),
                        }),
                        None,
                        &mut recv_buf,
                    )?;
                }
                // Wait for rank 1's "all received" note: its give-backs
                // happened-before that send, so the drain below sees them.
                let done = t.sendrecv_into(None, Some(1), &mut recv_buf)?;
                assert_eq!(done, Some(99));
                let mut came_home = 0;
                while t.take_back[1].try_recv().is_ok() {
                    came_home += 1;
                }
                Ok(came_home)
            } else {
                for _ in 0..50 {
                    let got = t.sendrecv_into(None, Some(0), &mut recv_buf)?;
                    assert!(got.is_some());
                    assert_eq!(recv_buf.len(), 256);
                }
                t.sendrecv_into(
                    Some(SendSpec {
                        to: 0,
                        tag: 99,
                        data: Payload::Bytes(&[]),
                    }),
                    None,
                    &mut recv_buf,
                )?;
                Ok(0)
            }
        })
        .unwrap();
        assert!(results[0] > 0, "no buffers were recycled: {results:?}");
    }
}
