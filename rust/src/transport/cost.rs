//! Cost-model transport: the lockstep all-ranks-in-one-process backend
//! whose rounds are charged to a [`CostModel`] through the [`Engine`]'s
//! accounting — the single execution core behind the paper's figure and
//! table sweeps.
//!
//! Every rank runs on its own OS thread (spawned with a small stack so
//! `p = 1152` is cheap), but communication is globally round-synchronous:
//! a round executes once all `p` endpoints have called
//! [`Transport::sendrecv_into`], at which point the collected messages go
//! through [`Engine::exchange_into`] — so the one-ported machine model is
//! *enforced* and every round is priced at its maximum `α + β·bytes` edge
//! cost.
//!
//! Two payload modes share this code path:
//!
//! * **Real bytes** ([`Payload::Bytes`]) are copied into the round (the
//!   copy is the simulator's price, not the machine model's) and
//!   delivered byte-exactly — the reference behavior the cross-backend
//!   tests compare thread/tcp against (see [`super::sim`]).
//! * **Virtual payloads** ([`Payload::Virtual`]) carry only a size:
//!   the engine accounts the declared bytes and the receiver gets a
//!   size-only frame (empty receive buffer). This is what lets the
//!   `p = 1152` sweeps run gigabyte messages through the *same* rank-local
//!   collectives that move real bytes, without ever allocating a payload.
//!
//! [`run_cost`] is the SPMD harness; it returns the per-rank results plus
//! the engine's round/byte/time accounting. Round buffers (the message
//! vector and the delivery inbox) are reused across rounds, so a
//! steady-state virtual round performs no payload-sized allocations —
//! pinned by `rust/tests/cost_transport.rs`.

use super::{CostHint, Payload, SendSpec, Transport, TransportError};
use crate::simulator::{CostModel, Engine, Msg, SimError, Stats};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

/// Stack size for the per-rank threads of [`run_cost`]: the SPMD
/// collectives keep their state on the heap, so 512 KiB leaves ample
/// headroom while letting `p` in the thousands spawn cheaply.
const COST_STACK_BYTES: usize = 512 * 1024;

struct Round {
    engine: Engine,
    /// Sends collected for the round being assembled (reused across
    /// rounds; drained by [`Engine::exchange_into`]).
    msgs: Vec<Msg>,
    /// Delivery slots of the last executed round (index = receiver rank;
    /// reused across rounds).
    inbox: Vec<Option<Msg>>,
    /// Endpoints that have called into the round being assembled.
    submitted: u64,
    /// Bumped once per executed round; waiters key on it.
    generation: u64,
    /// Endpoints that have been dropped (normally all-at-once at program
    /// end; early departures fail later rounds instead of hanging them).
    departed: u64,
    /// Sticky first failure; every subsequent call observes it.
    error: Option<SimError>,
    /// Simulated time at the start of the last executed round — what the
    /// recorder stamps `t_start` with (simulated, not wall-clock, time).
    #[cfg(feature = "obs")]
    time_before_s: f64,
}

struct Shared {
    p: u64,
    round: Mutex<Round>,
    cv: Condvar,
}

fn lock(m: &Mutex<Round>) -> MutexGuard<'_, Round> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// One rank's endpoint of the lockstep cost-model transport. Create a
/// full set with [`run_cost`] (or [`super::sim::run_sim`], the data-mode
/// veneer).
pub struct CostTransport {
    rank: u64,
    cost: CostModel,
    shared: Arc<Shared>,
}

impl Transport for CostTransport {
    fn rank(&self) -> u64 {
        self.rank
    }

    fn size(&self) -> u64 {
        self.shared.p
    }

    fn cost_hint(&self) -> CostHint {
        CostHint::from_model(&self.cost)
    }

    fn sendrecv_into(
        &mut self,
        send: Option<SendSpec<'_>>,
        recv_from: Option<u64>,
        recv_buf: &mut Vec<u8>,
    ) -> Result<Option<u64>, TransportError> {
        let sh = &self.shared;
        let mut st = lock(&sh.round);
        if st.departed > 0 && st.error.is_none() {
            // A peer is gone for good; this round can never fill up.
            st.error = Some(SimError::Collective(
                "a rank exited before the collective completed".into(),
            ));
            sh.cv.notify_all();
        }
        if let Some(e) = &st.error {
            return Err(TransportError::Sim(e.clone()));
        }
        let gen = st.generation;
        #[cfg(feature = "obs")]
        let sent_info = send.as_ref().map(|s| (s.to, s.tag, s.data.len()));
        if let Some(s) = send {
            // Real payloads are owned across the round boundary (the copy
            // is the simulator's price, not the machine model's); virtual
            // payloads carry only their declared size.
            let (bytes, data) = match s.data {
                Payload::Bytes(b) => (b.len() as u64, Some(b.to_vec())),
                Payload::Virtual(len) => (len, None),
            };
            st.msgs.push(Msg {
                from: self.rank,
                to: s.to,
                bytes,
                tag: s.tag,
                data,
            });
        }
        st.submitted += 1;
        if st.submitted == sh.p {
            // Last rank in: execute the round for everyone, reusing the
            // round buffers (no per-round allocation in steady state).
            #[cfg(feature = "obs")]
            {
                st.time_before_s = st.engine.stats().time_s;
            }
            let Round {
                ref mut engine,
                ref mut msgs,
                ref mut inbox,
                ..
            } = *st;
            if let Err(e) = engine.exchange_into(msgs, inbox) {
                st.error = Some(e);
            }
            st.submitted = 0;
            st.generation = gen + 1;
            sh.cv.notify_all();
        } else {
            while st.generation == gen && st.error.is_none() {
                st = sh.cv.wait(st).unwrap_or_else(|e| e.into_inner());
            }
        }
        if let Some(e) = &st.error {
            return Err(TransportError::Sim(e.clone()));
        }
        let got = st.inbox[self.rank as usize].take();
        #[cfg(feature = "obs")]
        let round_start_s = st.time_before_s;
        drop(st);
        // Record the rank's *own* edge at its own α + β·bytes cost (not
        // the global round maximum), so calibration sees exact linear
        // samples; timestamps are simulated time.
        #[cfg(feature = "obs")]
        if crate::obs::is_active() {
            let recv_info = got.as_ref().map(|m| (m.from, m.tag, m.bytes));
            let dur_s = match (&sent_info, &recv_info) {
                (Some((to, _, bytes)), _) => self.cost.edge_cost(self.rank, *to, *bytes),
                (None, Some((from, _, bytes))) => self.cost.edge_cost(*from, self.rank, *bytes),
                (None, None) => 0.0,
            };
            crate::obs::record_sim(sent_info, recv_info, round_start_s, dur_s);
        }
        match (got, recv_from) {
            (None, None) => Ok(None),
            (Some(msg), Some(from)) => {
                if msg.from != from {
                    return Err(TransportError::protocol(format!(
                        "rank {}: scheduled receive from {from}, message came from {}",
                        self.rank, msg.from
                    )));
                }
                recv_buf.clear();
                if let Some(data) = &msg.data {
                    recv_buf.extend_from_slice(data);
                }
                Ok(Some(msg.tag))
            }
            (Some(msg), None) => Err(TransportError::protocol(format!(
                "rank {}: unscheduled message from {} (block {})",
                self.rank, msg.from, msg.tag
            ))),
            (None, Some(from)) => Err(TransportError::Collective(format!(
                "rank {}: scheduled block from {from} never arrived",
                self.rank
            ))),
        }
    }

    fn barrier(&mut self) -> Result<(), TransportError> {
        // An empty exchange synchronizes all ranks; the engine does not
        // account empty rounds, so a barrier is free in simulated time.
        let mut scratch = Vec::new();
        match self.sendrecv_into(None, None, &mut scratch)? {
            None => Ok(()),
            Some(_) => unreachable!("sendrecv(None, None) validated the empty inbox"),
        }
    }
}

impl Drop for CostTransport {
    fn drop(&mut self) {
        // If this endpoint exits (error or panic) while peers are waiting
        // on a round it will never join, fail the round loudly instead of
        // letting them block forever. Under the SPMD contract a normal
        // exit never observes a pending round.
        let sh = &self.shared;
        let mut st = lock(&sh.round);
        st.departed += 1;
        if st.submitted > 0 && st.error.is_none() {
            st.error = Some(SimError::Collective(format!(
                "rank {} exited while a round was pending",
                self.rank
            )));
            st.submitted = 0;
            st.generation += 1;
            sh.cv.notify_all();
        }
    }
}

/// Run `f` as an SPMD program: one small-stack OS thread per rank, each
/// with its own [`CostTransport`] endpoint, all communicating through one
/// [`Engine`] under `cost`.
///
/// Returns the per-rank results (index = rank) and the engine's final
/// accounting. If any rank fails, the first substantive error is returned
/// (abort-notifications raised on other ranks by the failure are
/// suppressed in its favor).
pub fn run_cost<R, F>(p: u64, cost: CostModel, f: F) -> Result<(Vec<R>, Stats), TransportError>
where
    R: Send,
    F: Fn(CostTransport) -> Result<R, TransportError> + Sync,
{
    assert!(p >= 1, "need at least one rank");
    let shared = Arc::new(Shared {
        p,
        round: Mutex::new(Round {
            engine: Engine::new(p, cost),
            msgs: Vec::new(),
            inbox: (0..p).map(|_| None).collect(),
            submitted: 0,
            generation: 0,
            departed: 0,
            error: None,
            #[cfg(feature = "obs")]
            time_before_s: 0.0,
        }),
        cv: Condvar::new(),
    });
    let mut results: Vec<Option<Result<R, TransportError>>> = (0..p).map(|_| None).collect();
    let mut spawn_err: Option<String> = None;
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(p as usize);
        for rank in 0..p {
            let shared_for_rank = Arc::clone(&shared);
            let f = &f;
            let spawned = std::thread::Builder::new()
                .name(format!("nblk-cost-{rank}"))
                .stack_size(COST_STACK_BYTES)
                .spawn_scoped(s, move || {
                    f(CostTransport {
                        rank,
                        cost,
                        shared: shared_for_rank,
                    })
                });
            match spawned {
                Ok(h) => handles.push(h),
                Err(e) => {
                    // Abort the ranks already running: they wait on a
                    // round that can never fill without rank `rank`.
                    let mut st = lock(&shared.round);
                    st.error = Some(SimError::Collective(format!(
                        "could not spawn rank {rank} of {p}: {e}"
                    )));
                    shared.cv.notify_all();
                    drop(st);
                    spawn_err = Some(format!(
                        "could not spawn rank {rank} of {p} (raise the process/thread \
                         limits or reduce p): {e}"
                    ));
                    break;
                }
            }
        }
        for (rank, h) in handles.into_iter().enumerate() {
            results[rank] = Some(h.join().unwrap_or_else(|_| {
                Err(TransportError::Collective(format!("rank {rank} panicked")))
            }));
        }
    });
    if let Some(msg) = spawn_err {
        return Err(TransportError::io(msg));
    }
    let out = super::drain_results(results, is_abort_notification)?;
    let stats = lock(&shared.round).engine.stats();
    Ok((out, stats))
}

/// True for the secondary errors ranks observe when a *different* rank
/// aborted a pending round (see `Drop`).
pub(super) fn is_abort_notification(e: &TransportError) -> bool {
    matches!(e, TransportError::Sim(SimError::Collective(msg))
        if msg.contains("exited while a round was pending")
            || msg.contains("exited before the collective completed"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn virtual_round_accounts_without_bytes() {
        // A ring shift of 1 MiB virtual blocks: accounted, never stored.
        let p = 4u64;
        let m = 1u64 << 20;
        let (_, stats) = run_cost(
            p,
            CostModel::Flat {
                alpha: 0.0,
                beta: 1.0,
            },
            |mut t| {
                let r = t.rank();
                let mut buf = vec![0xAAu8; 3]; // sentinel: must be cleared
                let got = t.sendrecv_into(
                    Some(SendSpec {
                        to: (r + 1) % p,
                        tag: 7,
                        data: Payload::Virtual(m),
                    }),
                    Some((r + p - 1) % p),
                    &mut buf,
                )?;
                assert_eq!(got, Some(7));
                assert!(buf.is_empty(), "virtual frames carry no bytes");
                Ok(())
            },
        )
        .unwrap();
        assert_eq!(stats.rounds, 1);
        assert_eq!(stats.bytes_on_wire, p * m);
        assert!((stats.time_s - m as f64).abs() < 1e-9);
    }

    #[test]
    fn mixed_real_and_virtual_in_one_round() {
        let (_, stats) = run_cost(
            3,
            CostModel::Flat {
                alpha: 1.0,
                beta: 0.0,
            },
            |mut t| {
                let mut buf = Vec::new();
                match t.rank() {
                    0 => {
                        // Real bytes to rank 1.
                        t.sendrecv_into(
                            Some(SendSpec {
                                to: 1,
                                tag: 0,
                                data: Payload::Bytes(&[9, 9]),
                            }),
                            None,
                            &mut buf,
                        )?;
                        Ok(0usize)
                    }
                    1 => {
                        let got = t.sendrecv_into(None, Some(0), &mut buf)?;
                        assert_eq!(got, Some(0));
                        assert_eq!(buf, vec![9, 9]);
                        Ok(buf.len())
                    }
                    _ => {
                        // Virtual bytes to nobody: an idle round.
                        t.sendrecv_into(None, None, &mut buf)?;
                        Ok(0)
                    }
                }
            },
        )
        .unwrap();
        assert_eq!(stats.rounds, 1);
        assert_eq!(stats.bytes_on_wire, 2);
    }

    #[test]
    fn cost_hint_comes_from_the_model() {
        let model = CostModel::Flat {
            alpha: 4.0e-6,
            beta: 1.0e-9,
        };
        let (hints, _) = run_cost(2, model, |mut t| {
            let h = t.cost_hint();
            t.barrier()?;
            Ok(h.latency_cutoff_bytes())
        })
        .unwrap();
        assert_eq!(hints, vec![4000, 4000]);
    }
}
