//! Cross-process shared-memory transport: per-link SPSC ring buffers in
//! one memmap'd segment file.
//!
//! Ranks on the same host exchange blocks at memory speed instead of
//! paying a loopback-TCP round trip. The segment is an ordinary file
//! (preferably on `/dev/shm`) mapped `MAP_SHARED` by every rank:
//!
//! ```text
//! [SegHdr 64 B][ring table: p·p offsets][arena: rings, allocated lazily]
//! ```
//!
//! Each *directed* pair `(from, to)` owns at most one single-producer /
//! single-consumer byte ring, created by its producer on first use (the
//! arena is a sparse file, so untouched rings cost no memory — a p = 512
//! mesh only materializes the `O(p log p)` rings the schedules actually
//! drive). Frames mirror the TCP wire format — `[tag u64][len u64]
//! [payload]`, little-endian — and are written *chunked*: a frame larger
//! than the ring streams through it, the producer copying directly from
//! the caller's borrowed [`Payload::Bytes`] into the ring and the consumer
//! copying directly into the caller's reusable receive buffer. One copy
//! in, one copy out, zero intermediate buffers, zero steady-state heap
//! allocations.
//!
//! ## Wakeup protocol
//!
//! Progress never *depends* on wakeups: both sides run a
//! spin-then-park loop bounded by the operation deadline. A blocked side
//! raises its waiter flag (`data_waiter` for an empty ring,
//! `space_waiter` for a full one), re-checks the counters, and parks on
//! the flag with a short-bounded futex wait (plain `syscall(SYS_futex)`,
//! cross-process mode; non-Linux hosts fall back to a short sleep). The
//! peer clears-and-wakes the flag after advancing its counter, so lost
//! races degrade to at most one bounded park, never a hang.
//!
//! ## Rendezvous
//!
//! A creator ([`Segment::create`]) sizes the file, initializes the
//! header, and publishes it by storing the magic *last* (release order);
//! attachers ([`Segment::open`]) spin until the magic appears. The
//! `launch` CLI subcommand creates the segment in the parent and hands
//! children the path — see [`crate::transport::bootstrap`] for the
//! cross-host half.

use super::{CostHint, FaultCtx, Payload, SendSpec, Transport, TransportError};
use std::fs::{File, OpenOptions};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Segment header magic, stored last by the creator (release order) so an
/// attacher that observes it also observes the initialized header.
pub const SEG_MAGIC: u64 = u64::from_le_bytes(*b"nblkShm1");

/// Bytes reserved for the segment header.
const SEG_HDR_BYTES: u64 = 64;

/// Bytes reserved for each ring's header (head and tail live on separate
/// cache lines so the producer and consumer never false-share).
const RING_HDR_BYTES: u64 = 128;

/// Frame header: `[tag u64][len u64]`, mirroring the TCP wire format.
const FRAME_HDR_BYTES: usize = 16;

/// Frames above this are rejected as corrupt (same bound as the TCP
/// backend's frame reader).
const MAX_FRAME: u64 = 1 << 32;

/// How long a blocked side parks per futex wait before re-checking the
/// deadline (lost wakeup races therefore cost at most this much).
const PARK_SLICE: Duration = Duration::from_millis(1);

/// Spin iterations before parking — covers the common case where the
/// peer is mid-round on another core.
const SPIN_BEFORE_PARK: u32 = 256;

/// The static pre-warm-up `α + β·bytes` hint of the shared-memory link
/// class: sub-microsecond startup, memory-speed bandwidth (~10 GB/s).
/// [`Transport::warm_up`] replaces it with a measured fit.
pub const SHM_STATIC_HINT: CostHint = CostHint {
    alpha_s: 4.0e-7,
    beta_s_per_byte: 1.0e-10,
};

/// The default per-link ring capacity for a `p`-rank segment: generous
/// while the mesh is small, tighter as `p²` sparse-file bookkeeping and
/// the touched-ring footprint grow.
pub fn default_ring_cap(p: u64) -> u64 {
    if p <= 32 {
        256 * 1024
    } else if p <= 128 {
        64 * 1024
    } else {
        16 * 1024
    }
}

/// The preferred directory for segment files: `/dev/shm` (a tmpfs on
/// Linux) when present, the system temp dir otherwise.
pub fn default_segment_dir() -> PathBuf {
    let shm = Path::new("/dev/shm");
    if shm.is_dir() {
        shm.to_path_buf()
    } else {
        std::env::temp_dir()
    }
}

/// A collision-resistant segment path under [`default_segment_dir`],
/// namespaced by the calling process id.
pub fn segment_path(label: &str) -> PathBuf {
    default_segment_dir().join(format!("nblk-shm-{}-{label}", std::process::id()))
}

// --- raw mmap ---------------------------------------------------------

#[cfg(unix)]
mod mm {
    use std::os::fd::AsRawFd;
    use std::os::raw::{c_int, c_void};

    const PROT_READ: c_int = 1;
    const PROT_WRITE: c_int = 2;
    const MAP_SHARED: c_int = 1;

    extern "C" {
        fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        fn munmap(addr: *mut c_void, len: usize) -> c_int;
    }

    /// Map `len` bytes of `file` shared read-write.
    pub fn map_shared(file: &std::fs::File, len: usize) -> std::io::Result<*mut u8> {
        let ptr = unsafe {
            mmap(
                std::ptr::null_mut(),
                len,
                PROT_READ | PROT_WRITE,
                MAP_SHARED,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr as isize == -1 || ptr.is_null() {
            return Err(std::io::Error::last_os_error());
        }
        Ok(ptr as *mut u8)
    }

    /// Unmap a [`map_shared`] mapping.
    pub fn unmap(ptr: *mut u8, len: usize) {
        unsafe {
            munmap(ptr as *mut c_void, len);
        }
    }
}

// --- futex wakeups ----------------------------------------------------

#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
mod park {
    use std::os::raw::{c_int, c_long};
    use std::sync::atomic::AtomicU32;
    use std::time::Duration;

    #[cfg(target_arch = "x86_64")]
    const SYS_FUTEX: c_long = 202;
    #[cfg(target_arch = "aarch64")]
    const SYS_FUTEX: c_long = 98;
    // Non-private ops: the waiter and waker are different processes.
    const FUTEX_WAIT: c_int = 0;
    const FUTEX_WAKE: c_int = 1;

    #[repr(C)]
    struct Timespec {
        sec: i64,
        nsec: i64,
    }

    extern "C" {
        fn syscall(num: c_long, ...) -> c_long;
    }

    /// Sleep while `*flag == expected`, at most `timeout`. Spurious
    /// returns are fine — every caller re-checks its condition.
    pub fn wait(flag: &AtomicU32, expected: u32, timeout: Duration) {
        let ts = Timespec {
            sec: timeout.as_secs() as i64,
            nsec: i64::from(timeout.subsec_nanos()),
        };
        unsafe {
            syscall(
                SYS_FUTEX,
                flag as *const AtomicU32,
                FUTEX_WAIT,
                expected,
                &ts as *const Timespec,
                0usize,
                0u32,
            );
        }
    }

    /// Wake every waiter parked on `flag`.
    pub fn wake(flag: &AtomicU32) {
        unsafe {
            syscall(
                SYS_FUTEX,
                flag as *const AtomicU32,
                FUTEX_WAKE,
                i32::MAX,
                0usize,
                0usize,
                0u32,
            );
        }
    }
}

#[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
mod park {
    use std::sync::atomic::AtomicU32;
    use std::time::Duration;

    /// Portable fallback: a short sleep instead of a futex wait — the
    /// spin-then-park loops are deadline-bounded either way.
    pub fn wait(_flag: &AtomicU32, _expected: u32, timeout: Duration) {
        std::thread::sleep(timeout.min(Duration::from_micros(200)));
    }

    /// No-op: the fallback waiter polls.
    pub fn wake(_flag: &AtomicU32) {}
}

// --- segment layout ---------------------------------------------------

/// The segment header (64 bytes at offset 0). All fields are atomics
/// because they are shared across processes; `magic` is stored last by
/// the creator, with release order, to publish the rest.
#[repr(C)]
struct SegHdr {
    magic: AtomicU64,
    p: AtomicU64,
    ring_cap: AtomicU64,
    /// Bump allocator over the arena: next free byte offset.
    alloc_next: AtomicU64,
    _reserved: [u64; 4],
}

/// One ring's header: producer cache line (monotonic byte offset written
/// by the producer + the consumer's waiter flag it wakes), then the
/// consumer cache line mirroring it.
#[repr(C)]
struct RingHdr {
    /// Total bytes ever written (monotonic; producer-owned).
    head: AtomicU64,
    /// Raised by a consumer about to park on an empty ring.
    data_waiter: AtomicU32,
    _pad0: [u8; 52],
    /// Total bytes ever read (monotonic; consumer-owned).
    tail: AtomicU64,
    /// Raised by a producer about to park on a full ring.
    space_waiter: AtomicU32,
    _pad1: [u8; 52],
}

/// Byte layout of a `p`-rank segment with per-link capacity `ring_cap`.
fn seg_layout(p: u64, ring_cap: u64) -> (u64, u64) {
    let table_bytes = p * p * 8;
    let arena_off = (SEG_HDR_BYTES + table_bytes).div_ceil(64) * 64;
    let ring_bytes = RING_HDR_BYTES + ring_cap;
    // Worst case every directed pair allocates a ring; the file is
    // sparse, so only touched rings occupy memory.
    let total = arena_off + p * p.saturating_sub(1) * ring_bytes;
    (arena_off, total)
}

/// One mapped shared-memory segment: the rendezvous object every
/// same-host rank attaches to. Create once ([`Segment::create`]), attach
/// from anywhere ([`Segment::open`] cross-process, [`Arc`] clones
/// in-process). The creator's `Drop` unlinks the file.
pub struct Segment {
    base: *mut u8,
    len: usize,
    path: PathBuf,
    unlink: bool,
}

// SAFETY: the mapping is plain shared memory; all cross-thread access
// goes through the atomics in `SegHdr`/`RingHdr` with acquire/release
// pairs, exactly as it does cross-process.
unsafe impl Send for Segment {}
unsafe impl Sync for Segment {}

impl Segment {
    /// Create and publish a fresh `p`-rank segment at `path` (truncating
    /// any stale file). `ring_cap` is the per-link ring capacity in bytes
    /// (multiple of 64, at least 1024 — see [`default_ring_cap`]). The
    /// returned handle owns the file: dropping it unlinks `path`.
    pub fn create(path: &Path, p: u64, ring_cap: u64) -> Result<Segment, TransportError> {
        if p == 0 {
            return Err(TransportError::protocol("need at least one rank".into()));
        }
        if ring_cap < 1024 || ring_cap % 64 != 0 {
            return Err(TransportError::protocol(format!(
                "ring capacity {ring_cap} must be a multiple of 64, at least 1024"
            )));
        }
        let (arena_off, total) = seg_layout(p, ring_cap);
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(path)
            .map_err(|e| TransportError::io(format!("creating segment {}: {e}", path.display())))?;
        file.set_len(total)
            .map_err(|e| TransportError::io(format!("sizing segment {}: {e}", path.display())))?;
        let base = mm::map_shared(&file, total as usize)
            .map_err(|e| TransportError::io(format!("mapping segment {}: {e}", path.display())))?;
        let seg = Segment {
            base,
            len: total as usize,
            path: path.to_path_buf(),
            unlink: true,
        };
        let hdr = seg.hdr();
        hdr.p.store(p, Ordering::Relaxed);
        hdr.ring_cap.store(ring_cap, Ordering::Relaxed);
        hdr.alloc_next.store(arena_off, Ordering::Relaxed);
        // Publish: attachers spinning on the magic see the header above.
        hdr.magic.store(SEG_MAGIC, Ordering::Release);
        Ok(seg)
    }

    /// Attach to a segment some other process created, retrying until the
    /// file exists and its magic is published or `deadline` passes. The
    /// returned handle does *not* unlink the file on drop.
    pub fn open(path: &Path, deadline: Instant) -> Result<Segment, TransportError> {
        loop {
            if let Some(seg) = Segment::try_open(path)? {
                return Ok(seg);
            }
            if Instant::now() >= deadline {
                return Err(TransportError::timeout(format!(
                    "segment {} was not published in time",
                    path.display()
                )));
            }
            std::thread::sleep(Duration::from_micros(200));
        }
    }

    /// One attach attempt: `Ok(None)` when the segment is not published
    /// yet (missing file, zero length, magic not stored).
    fn try_open(path: &Path) -> Result<Option<Segment>, TransportError> {
        let file = match File::options().read(true).write(true).open(path) {
            Ok(f) => f,
            Err(_) => return Ok(None),
        };
        let len = file
            .metadata()
            .map_err(|e| TransportError::io(format!("stat {}: {e}", path.display())))?
            .len();
        if len < SEG_HDR_BYTES {
            return Ok(None);
        }
        let base = mm::map_shared(&file, len as usize)
            .map_err(|e| TransportError::io(format!("mapping segment {}: {e}", path.display())))?;
        let seg = Segment {
            base,
            len: len as usize,
            path: path.to_path_buf(),
            unlink: false,
        };
        let magic = seg.hdr().magic.load(Ordering::Acquire);
        if magic != SEG_MAGIC {
            if magic != 0 {
                return Err(TransportError::protocol(format!(
                    "segment {}: bad magic {magic:#x}",
                    path.display()
                )));
            }
            return Ok(None); // not published yet; Drop unmaps
        }
        Ok(Some(seg))
    }

    fn hdr(&self) -> &SegHdr {
        // SAFETY: the mapping is at least SEG_HDR_BYTES long (checked at
        // create/open) and page-aligned.
        unsafe { &*(self.base as *const SegHdr) }
    }

    /// Number of ranks this segment was created for.
    pub fn ranks(&self) -> u64 {
        self.hdr().p.load(Ordering::Relaxed)
    }

    /// Per-link ring capacity in bytes.
    pub fn ring_capacity(&self) -> u64 {
        self.hdr().ring_cap.load(Ordering::Relaxed)
    }

    /// The backing file path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// The ring-table entry for the directed link `from → to` (a byte
    /// offset into the segment; 0 = not yet allocated).
    fn table_entry(&self, from: u64, to: u64) -> &AtomicU64 {
        let p = self.ranks();
        debug_assert!(from < p && to < p);
        let off = SEG_HDR_BYTES + (from * p + to) * 8;
        // SAFETY: in bounds by layout; 8-aligned.
        unsafe { &*(self.base.add(off as usize) as *const AtomicU64) }
    }

    /// View the ring at byte offset `off`.
    fn ring_at(&self, off: u64) -> Ring {
        debug_assert!(off as usize + (RING_HDR_BYTES as usize) <= self.len);
        Ring {
            // SAFETY: offsets come from the bump allocator, which is
            // bounds-checked against the mapping length.
            hdr: unsafe { self.base.add(off as usize) as *const RingHdr },
            data: unsafe { self.base.add((off + RING_HDR_BYTES) as usize) },
            cap: self.ring_capacity(),
        }
    }

    /// The producer-side lookup: the ring `from → to`, allocating it from
    /// the arena on first use. Only the producer (`from`) may call this,
    /// which is what makes the table store race-free.
    fn producer_ring(&self, from: u64, to: u64) -> Result<Ring, TransportError> {
        let entry = self.table_entry(from, to);
        let mut off = entry.load(Ordering::Acquire);
        if off == 0 {
            let ring_bytes = RING_HDR_BYTES + self.ring_capacity();
            off = self.hdr().alloc_next.fetch_add(ring_bytes, Ordering::Relaxed);
            if off + ring_bytes > self.len as u64 {
                return Err(TransportError::protocol_at(
                    format!(
                        "segment {} arena exhausted allocating ring {from}->{to}",
                        self.path.display()
                    ),
                    FaultCtx::peer(to),
                ));
            }
            // Fresh pages of the sparse file are zero, which is exactly a
            // valid empty ring — no initialization pass needed.
            entry.store(off, Ordering::Release);
        }
        Ok(self.ring_at(off))
    }

    /// The consumer-side lookup: `None` until the producer allocates.
    fn consumer_ring(&self, from: u64, to: u64) -> Option<Ring> {
        let off = self.table_entry(from, to).load(Ordering::Acquire);
        (off != 0).then(|| self.ring_at(off))
    }
}

impl Drop for Segment {
    fn drop(&mut self) {
        mm::unmap(self.base, self.len);
        if self.unlink {
            let _ = std::fs::remove_file(&self.path);
        }
    }
}

/// A resolved SPSC byte ring: header plus `cap` data bytes. `Copy` so the
/// per-peer caches hand it out cheaply; all state lives in shared memory.
#[derive(Clone, Copy)]
struct Ring {
    hdr: *const RingHdr,
    data: *mut u8,
    cap: u64,
}

impl Ring {
    fn hdr(&self) -> &RingHdr {
        // SAFETY: points into a live Segment mapping (the transport holds
        // the Arc for as long as any Ring is reachable).
        unsafe { &*self.hdr }
    }

    /// Producer side: copy as much of `src` as fits, advance `head`, wake
    /// a parked consumer. Returns the bytes consumed from `src`.
    fn push(&self, src: &[u8]) -> usize {
        let h = self.hdr();
        let head = h.head.load(Ordering::Relaxed);
        let tail = h.tail.load(Ordering::Acquire);
        let space = self.cap - (head - tail);
        let n = (space as usize).min(src.len());
        if n == 0 {
            return 0;
        }
        let pos = (head % self.cap) as usize;
        let first = n.min(self.cap as usize - pos);
        // SAFETY: [pos, pos + first) and [0, n - first) are in the data
        // area and, by the SPSC head/tail protocol, not concurrently read.
        unsafe {
            std::ptr::copy_nonoverlapping(src.as_ptr(), self.data.add(pos), first);
            std::ptr::copy_nonoverlapping(src.as_ptr().add(first), self.data, n - first);
        }
        h.head.store(head + n as u64, Ordering::Release);
        if h.data_waiter.swap(0, Ordering::AcqRel) == 1 {
            park::wake(&h.data_waiter);
        }
        n
    }

    /// Consumer side: feed up to `max` buffered bytes to `sink` (in one
    /// or two slices at the wrap point), advance `tail`, wake a parked
    /// producer. Returns the bytes drained.
    fn pull(&self, max: usize, mut sink: impl FnMut(&[u8])) -> usize {
        let h = self.hdr();
        let head = h.head.load(Ordering::Acquire);
        let tail = h.tail.load(Ordering::Relaxed);
        let avail = head - tail;
        let n = (avail as usize).min(max);
        if n == 0 {
            return 0;
        }
        let pos = (tail % self.cap) as usize;
        let first = n.min(self.cap as usize - pos);
        // SAFETY: the producer never writes [tail, head) while the
        // consumer holds it; slices are in the data area.
        unsafe {
            sink(std::slice::from_raw_parts(self.data.add(pos), first));
            sink(std::slice::from_raw_parts(self.data, n - first));
        }
        h.tail.store(tail + n as u64, Ordering::Release);
        if h.space_waiter.swap(0, Ordering::AcqRel) == 1 {
            park::wake(&h.space_waiter);
        }
        n
    }

    /// Bytes buffered and unread (consumer view).
    fn buffered(&self) -> u64 {
        self.hdr().head.load(Ordering::Acquire) - self.hdr().tail.load(Ordering::Relaxed)
    }

    /// Free capacity (producer view).
    fn space(&self) -> u64 {
        self.cap - (self.hdr().head.load(Ordering::Relaxed) - self.hdr().tail.load(Ordering::Acquire))
    }
}

/// In-flight outgoing frame: header then the caller's borrowed payload,
/// streamed straight into the peer ring.
struct SendProgress<'a> {
    hdr: [u8; FRAME_HDR_BYTES],
    hdr_pos: usize,
    data: &'a [u8],
    data_pos: usize,
}

impl<'a> SendProgress<'a> {
    fn new(tag: u64, data: &'a [u8]) -> SendProgress<'a> {
        let mut hdr = [0u8; FRAME_HDR_BYTES];
        hdr[..8].copy_from_slice(&tag.to_le_bytes());
        hdr[8..].copy_from_slice(&(data.len() as u64).to_le_bytes());
        SendProgress {
            hdr,
            hdr_pos: 0,
            data,
            data_pos: 0,
        }
    }

    fn step(&mut self, ring: Ring) -> bool {
        let mut progressed = false;
        if self.hdr_pos < FRAME_HDR_BYTES {
            let n = ring.push(&self.hdr[self.hdr_pos..]);
            self.hdr_pos += n;
            progressed |= n > 0;
        }
        if self.hdr_pos == FRAME_HDR_BYTES && self.data_pos < self.data.len() {
            let n = ring.push(&self.data[self.data_pos..]);
            self.data_pos += n;
            progressed |= n > 0;
        }
        progressed
    }

    fn done(&self) -> bool {
        self.hdr_pos == FRAME_HDR_BYTES && self.data_pos == self.data.len()
    }
}

/// In-flight incoming frame: header assembly, then payload bytes appended
/// to the caller's receive buffer.
struct RecvProgress {
    hdr: [u8; FRAME_HDR_BYTES],
    hdr_pos: usize,
    tag: u64,
    want: usize,
    parsed: bool,
}

impl RecvProgress {
    fn new() -> RecvProgress {
        RecvProgress {
            hdr: [0u8; FRAME_HDR_BYTES],
            hdr_pos: 0,
            tag: 0,
            want: 0,
            parsed: false,
        }
    }

    fn step(
        &mut self,
        ring: Ring,
        recv_buf: &mut Vec<u8>,
        rank: u64,
        from: u64,
        round: u64,
    ) -> Result<bool, TransportError> {
        let mut progressed = false;
        if !self.parsed {
            let need = FRAME_HDR_BYTES - self.hdr_pos;
            let hdr = &mut self.hdr;
            let mut pos = self.hdr_pos;
            let n = ring.pull(need, |chunk| {
                hdr[pos..pos + chunk.len()].copy_from_slice(chunk);
                pos += chunk.len();
            });
            self.hdr_pos = pos;
            progressed |= n > 0;
            if self.hdr_pos == FRAME_HDR_BYTES {
                self.tag = u64::from_le_bytes(self.hdr[..8].try_into().expect("8 bytes"));
                let len = u64::from_le_bytes(self.hdr[8..].try_into().expect("8 bytes"));
                if len > MAX_FRAME {
                    return Err(TransportError::protocol_at(
                        format!(
                            "rank {rank}: oversized frame from {from}: {len} bytes — corrupt ring"
                        ),
                        FaultCtx::peer(from).with_round(round),
                    ));
                }
                self.want = len as usize;
                self.parsed = true;
                recv_buf.clear();
                recv_buf.reserve(self.want);
            }
        }
        if self.parsed && recv_buf.len() < self.want {
            let need = self.want - recv_buf.len();
            let n = ring.pull(need, |chunk| recv_buf.extend_from_slice(chunk));
            progressed |= n > 0;
        }
        Ok(progressed)
    }

    fn done(&self, recv_buf: &[u8]) -> bool {
        self.parsed && recv_buf.len() == self.want
    }
}

/// One rank's endpoint of a shared-memory segment. Build a full in-process
/// set with [`run_shm`], or attach each process to a published segment
/// with [`ShmTransport::attach`] (the `launch` CLI subcommand does both
/// halves for you).
pub struct ShmTransport {
    seg: Arc<Segment>,
    rank: u64,
    p: u64,
    timeout: Duration,
    /// Cached rings this rank produces into (`rank → peer`).
    tx: Vec<Option<Ring>>,
    /// Cached rings this rank consumes (`peer → rank`).
    rx: Vec<Option<Ring>>,
    /// Warm-up α/β measurement; `None` until [`ShmTransport::warm_up`].
    measured: Option<CostHint>,
    /// Transport-level round counter for failure context.
    ops: u64,
}

// SAFETY: the cached `Ring` views point into the `Arc<Segment>` mapping
// this endpoint keeps alive; all shared state behind them is atomics with
// acquire/release pairs. Moving the whole endpoint to another thread
// moves both the rings and the Arc together, so the pointers stay valid
// and the SPSC roles (one producer, one consumer per ring) are preserved
// — they are per-*rank*, not per-thread.
unsafe impl Send for ShmTransport {}

impl ShmTransport {
    /// Rank `rank`'s endpoint over an already-mapped segment (in-process
    /// sharing: every rank clones the same [`Arc`]).
    pub fn from_segment(
        seg: Arc<Segment>,
        rank: u64,
        timeout: Duration,
    ) -> Result<ShmTransport, TransportError> {
        let p = seg.ranks();
        if rank >= p {
            return Err(TransportError::protocol(format!(
                "rank {rank} out of range for a {p}-rank segment"
            )));
        }
        Ok(ShmTransport {
            seg,
            rank,
            p,
            timeout,
            tx: (0..p).map(|_| None).collect(),
            rx: (0..p).map(|_| None).collect(),
            measured: None,
            ops: 0,
        })
    }

    /// Cross-process attach: map the segment at `path` (waiting up to
    /// `timeout` for the creator to publish it) and join as `rank`.
    pub fn attach(path: &Path, rank: u64, timeout: Duration) -> Result<ShmTransport, TransportError> {
        let seg = Segment::open(path, Instant::now() + timeout)?;
        ShmTransport::from_segment(Arc::new(seg), rank, timeout)
    }

    /// The segment this endpoint is attached to.
    pub fn segment(&self) -> &Arc<Segment> {
        &self.seg
    }

    fn tx_ring(&mut self, to: u64) -> Result<Ring, TransportError> {
        if let Some(r) = self.tx[to as usize] {
            return Ok(r);
        }
        let r = self.seg.producer_ring(self.rank, to)?;
        self.tx[to as usize] = Some(r);
        Ok(r)
    }

    fn rx_ring(&mut self, from: u64) -> Option<Ring> {
        if let Some(r) = self.rx[from as usize] {
            return Some(r);
        }
        let r = self.seg.consumer_ring(from, self.rank)?;
        self.rx[from as usize] = Some(r);
        Some(r)
    }

    fn check_peer(&self, peer: u64) -> Result<(), TransportError> {
        if peer >= self.p || peer == self.rank {
            return Err(TransportError::Collective(format!(
                "rank {}: invalid peer {peer} (p = {})",
                self.rank, self.p
            )));
        }
        Ok(())
    }

    /// The uninstrumented round body behind [`Transport::sendrecv_into`]:
    /// an interleaved full-duplex progress loop, so a send and a receive
    /// whose frames both exceed the ring capacity stream through it
    /// concurrently instead of deadlocking.
    fn round_impl(
        &mut self,
        send: Option<SendSpec<'_>>,
        recv_from: Option<u64>,
        recv_buf: &mut Vec<u8>,
    ) -> Result<Option<u64>, TransportError> {
        let round = self.ops;
        self.ops += 1;
        let mut tx = None;
        let mut sp = None;
        if let Some(s) = send {
            self.check_peer(s.to)?;
            let Payload::Bytes(data) = s.data else {
                // Size-only payloads belong to the cost-model backends;
                // this backend exists to move real bytes.
                return Err(TransportError::protocol_at(
                    format!(
                        "rank {}: virtual payload ({} bytes) on the shm backend \
                         — use the sim/cost backend for size-only sweeps",
                        self.rank,
                        s.data.len()
                    ),
                    FaultCtx::peer(s.to).with_round(round),
                ));
            };
            tx = Some((s.to, self.tx_ring(s.to)?));
            sp = Some(SendProgress::new(s.tag, data));
        }
        let mut rp = None;
        if let Some(from) = recv_from {
            self.check_peer(from)?;
            rp = Some(RecvProgress::new());
        }
        if sp.is_none() && rp.is_none() {
            return Ok(None);
        }
        let deadline = Instant::now() + self.timeout;
        let mut idle: u32 = 0;
        loop {
            let mut progressed = false;
            if let (Some(st), Some((_, ring))) = (sp.as_mut(), tx) {
                progressed |= st.step(ring);
                if st.done() {
                    sp = None;
                }
            }
            if let (Some(st), Some(from)) = (rp.as_mut(), recv_from) {
                if let Some(ring) = self.rx_ring(from) {
                    progressed |= st.step(ring, recv_buf, self.rank, from, round)?;
                    if st.done(recv_buf) {
                        let tag = st.tag;
                        rp = None;
                        if sp.is_none() {
                            return Ok(Some(tag));
                        }
                        // Stash the tag by re-entering with rp done.
                        return self.finish_send(sp, tx, deadline, round).map(|()| Some(tag));
                    }
                }
            }
            if sp.is_none() && rp.is_none() {
                return Ok(None);
            }
            if progressed {
                idle = 0;
                continue;
            }
            idle += 1;
            if idle <= SPIN_BEFORE_PARK {
                std::hint::spin_loop();
                continue;
            }
            if Instant::now() >= deadline {
                return Err(self.stall_error(send.map(|s| s.to), &sp, recv_from, &rp, round));
            }
            self.park_once(tx, recv_from);
        }
    }

    /// Drain the remaining outgoing bytes after the receive half finished.
    fn finish_send(
        &mut self,
        mut sp: Option<SendProgress<'_>>,
        tx: Option<(u64, Ring)>,
        deadline: Instant,
        round: u64,
    ) -> Result<(), TransportError> {
        let (to, ring) = tx.expect("send in progress implies a ring");
        let mut idle: u32 = 0;
        while let Some(st) = sp.as_mut() {
            if st.step(ring) {
                idle = 0;
                if st.done() {
                    sp = None;
                }
                continue;
            }
            idle += 1;
            if idle <= SPIN_BEFORE_PARK {
                std::hint::spin_loop();
                continue;
            }
            if Instant::now() >= deadline {
                return Err(TransportError::timeout_at(
                    format!(
                        "rank {}: waited {:?} for {to} to drain its ring",
                        self.rank, self.timeout
                    ),
                    FaultCtx::peer(to).with_round(round),
                ));
            }
            let h = ring.hdr();
            h.space_waiter.store(1, Ordering::SeqCst);
            if ring.space() == 0 {
                park::wait(&h.space_waiter, 1, PARK_SLICE);
            }
        }
        Ok(())
    }

    /// Park on whichever side is blocked (bounded by [`PARK_SLICE`], so a
    /// lost wakeup race or a simultaneous two-sided stall only costs one
    /// slice before re-checking). Reached only while at least one side is
    /// still pending: a pending receive parks on the data flag; otherwise
    /// the pending send parks on the space flag.
    fn park_once(&mut self, tx: Option<(u64, Ring)>, recv_from: Option<u64>) {
        if let Some(from) = recv_from {
            match self.rx_ring(from) {
                Some(ring) => {
                    let h = ring.hdr();
                    h.data_waiter.store(1, Ordering::SeqCst);
                    if ring.buffered() == 0 {
                        park::wait(&h.data_waiter, 1, PARK_SLICE);
                    }
                }
                None => {
                    // The peer has not allocated its ring yet: nothing to
                    // park on, poll gently.
                    std::thread::sleep(Duration::from_micros(50));
                }
            }
            return;
        }
        if let Some((_, ring)) = tx {
            let h = ring.hdr();
            h.space_waiter.store(1, Ordering::SeqCst);
            if ring.space() == 0 {
                park::wait(&h.space_waiter, 1, PARK_SLICE);
            }
        }
    }

    /// The structured timeout for a stalled round, naming the side(s)
    /// still pending.
    fn stall_error(
        &self,
        send_to: Option<u64>,
        sp: &Option<SendProgress<'_>>,
        recv_from: Option<u64>,
        rp: &Option<RecvProgress>,
        round: u64,
    ) -> TransportError {
        if let (Some(from), Some(_)) = (recv_from, rp.as_ref()) {
            return TransportError::timeout_at(
                format!(
                    "rank {}: waited {:?} for a block from {from} over shm",
                    self.rank, self.timeout
                ),
                FaultCtx::peer(from).with_round(round),
            );
        }
        let to = send_to.unwrap_or(u64::MAX);
        debug_assert!(sp.is_some());
        TransportError::timeout_at(
            format!(
                "rank {}: waited {:?} for {to} to drain its ring",
                self.rank, self.timeout
            ),
            FaultCtx::peer(to).with_round(round),
        )
    }
}

impl Transport for ShmTransport {
    fn rank(&self) -> u64 {
        self.rank
    }

    fn size(&self) -> u64 {
        self.p
    }

    fn sendrecv_into(
        &mut self,
        send: Option<SendSpec<'_>>,
        recv_from: Option<u64>,
        recv_buf: &mut Vec<u8>,
    ) -> Result<Option<u64>, TransportError> {
        #[cfg(feature = "obs")]
        let t0 = crate::obs::now_ns();
        #[cfg(feature = "obs")]
        let sent_info = send.map(|s| (s.to, s.tag, s.data.len()));
        let res = self.round_impl(send, recv_from, recv_buf);
        #[cfg(feature = "obs")]
        if let Ok(got) = &res {
            if let Some((_, _, bytes)) = sent_info {
                crate::obs::metrics::on_send(bytes);
            }
            let recv_info = got.map(|tag| {
                (
                    recv_from.expect("got implies recv_from"),
                    tag,
                    recv_buf.len() as u64,
                )
            });
            if let Some((_, _, bytes)) = recv_info {
                crate::obs::metrics::on_recv(bytes);
            }
            crate::obs::record_round(sent_info, recv_info, t0);
        }
        res
    }

    fn warm_up(&mut self) -> Result<(), TransportError> {
        // Pre-allocate the circulant rings this rank produces into, so
        // first rounds skip the arena bump. Failures downgrade to a
        // warning: the rings are allocated lazily on first use anyway.
        if self.p > 1 {
            let skips = crate::sched::Skips::new(self.p);
            for k in 0..skips.q() {
                let to = skips.to_proc(self.rank, k);
                let from = skips.from_proc(self.rank, k);
                if let Err(e) = self.tx_ring(to).and_then(|_| self.tx_ring(from)) {
                    super::warn_warm_up(self.rank, "ring pre-allocation", &e);
                    return Ok(());
                }
            }
        }
        // Measure α/β once (collective: every rank runs the same probe).
        // A timed-out or faulted probe keeps the static hint.
        if self.measured.is_none() {
            match super::measure_link_hint(self) {
                Ok(h) => self.measured = h,
                Err(e) => super::warn_warm_up(self.rank, "α/β probe", &e),
            }
        }
        Ok(())
    }

    fn warm_peers(&mut self, peers: &[u64]) -> Result<(), TransportError> {
        for &peer in peers {
            if peer != self.rank && peer < self.p {
                self.tx_ring(peer)?;
            }
        }
        Ok(())
    }

    fn cost_hint(&self) -> CostHint {
        self.measured.unwrap_or(SHM_STATIC_HINT)
    }

    fn barrier(&mut self) -> Result<(), TransportError> {
        super::dissemination_barrier(self)
    }
}

/// Run `f` as an SPMD program over one fresh shared-memory segment, one
/// OS thread per rank (the ring path is identical to the separate-process
/// mode; only the attach differs — threads share the mapping through an
/// [`Arc`]). Returns the per-rank results (index = rank); the segment
/// file is unlinked when the run ends.
pub fn run_shm<R, F>(p: u64, timeout: Duration, f: F) -> Result<Vec<R>, TransportError>
where
    R: Send,
    F: Fn(ShmTransport) -> Result<R, TransportError> + Sync,
{
    assert!(p >= 1, "need at least one rank");
    static RUN_SEQ: AtomicU64 = AtomicU64::new(0);
    let seq = RUN_SEQ.fetch_add(1, Ordering::Relaxed);
    let path = segment_path(&format!("run{seq}"));
    let seg = Arc::new(Segment::create(&path, p, default_ring_cap(p))?);
    let mut results: Vec<Option<Result<R, TransportError>>> = (0..p).map(|_| None).collect();
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(p as usize);
        for rank in 0..p {
            let f = &f;
            let seg = seg.clone();
            handles.push(s.spawn(move || {
                let t = ShmTransport::from_segment(seg, rank, timeout)?;
                f(t)
            }));
        }
        for (rank, h) in handles.into_iter().enumerate() {
            results[rank] = Some(h.join().unwrap_or_else(|_| {
                Err(TransportError::Collective(format!("rank {rank} panicked")))
            }));
        }
    });
    super::drain_results(results, |e| {
        matches!(
            e,
            TransportError::Timeout { .. } | TransportError::Io { .. }
        )
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pairwise_exchange_is_full_duplex() {
        let results = run_shm(4, Duration::from_secs(10), |mut t| {
            let partner = t.rank() ^ 1;
            let payload = [t.rank() as u8; 9];
            let got = t.sendrecv(
                Some(SendSpec {
                    to: partner,
                    tag: t.rank(),
                    data: Payload::Bytes(&payload),
                }),
                Some(partner),
            )?;
            let msg = got.expect("scheduled receive");
            t.barrier()?;
            Ok((msg.tag, msg.data))
        })
        .unwrap();
        for (r, (tag, data)) in results.iter().enumerate() {
            assert_eq!(*tag, r as u64 ^ 1);
            assert_eq!(data.as_slice(), [(r as u64 ^ 1) as u8; 9]);
        }
    }

    #[test]
    fn frames_larger_than_the_ring_stream_through() {
        // Frames 8× the ring capacity must stream: the interleaved
        // progress loop is what keeps cyclic full-duplex rounds alive.
        let path = segment_path("bigframe");
        let seg = Arc::new(Segment::create(&path, 2, 1024).unwrap());
        let big: Vec<u8> = (0..8 * 1024u64).map(|i| (i % 251) as u8).collect();
        let mut results: Vec<Option<Vec<u8>>> = vec![None, None];
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for rank in 0..2u64 {
                let seg = seg.clone();
                let big = &big;
                handles.push(s.spawn(move || {
                    let mut t =
                        ShmTransport::from_segment(seg, rank, Duration::from_secs(10)).unwrap();
                    let other = 1 - rank;
                    let mut buf = Vec::new();
                    let got = t
                        .sendrecv_into(
                            Some(SendSpec {
                                to: other,
                                tag: 7,
                                data: Payload::Bytes(big),
                            }),
                            Some(other),
                            &mut buf,
                        )
                        .unwrap();
                    assert_eq!(got, Some(7));
                    buf
                }));
            }
            for (rank, h) in handles.into_iter().enumerate() {
                results[rank] = Some(h.join().unwrap());
            }
        });
        for r in results {
            assert_eq!(r.unwrap(), big);
        }
    }

    #[test]
    fn fifo_per_pair_keeps_blocks_ordered() {
        let results = run_shm(2, Duration::from_secs(10), |mut t| {
            let mut tags = Vec::new();
            if t.rank() == 0 {
                for tag in 0..5u64 {
                    t.sendrecv(
                        Some(SendSpec {
                            to: 1,
                            tag,
                            data: Payload::Bytes(&[tag as u8; 3]),
                        }),
                        None,
                    )?;
                }
            } else {
                for _ in 0..5 {
                    let msg = t.sendrecv(None, Some(0))?.expect("scheduled receive");
                    tags.push(msg.tag);
                }
            }
            Ok(tags)
        })
        .unwrap();
        assert_eq!(results[1], vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn timeout_reports_instead_of_hanging() {
        let err = run_shm(2, Duration::from_millis(80), |mut t| {
            if t.rank() == 0 {
                return Ok(());
            }
            t.sendrecv(None, Some(0))?;
            Ok(())
        })
        .unwrap_err();
        assert!(
            matches!(
                err,
                TransportError::Timeout { .. } | TransportError::Io { .. }
            ),
            "{err}"
        );
    }

    #[test]
    fn virtual_payload_is_a_structured_protocol_error() {
        let err = run_shm(2, Duration::from_secs(5), |mut t| {
            if t.rank() == 0 {
                t.sendrecv(
                    Some(SendSpec {
                        to: 1,
                        tag: 0,
                        data: Payload::Virtual(1 << 20),
                    }),
                    None,
                )?;
            }
            Ok(())
        })
        .unwrap_err();
        match err {
            TransportError::Protocol { msg, .. } => {
                assert!(msg.contains("virtual payload"), "{msg}");
                assert!(msg.contains("shm backend"), "{msg}");
            }
            other => panic!("expected a Protocol error, got {other}"),
        }
    }

    #[test]
    fn warm_up_measures_a_positive_hint() {
        let hints = run_shm(3, Duration::from_secs(10), |mut t| {
            t.warm_up()?;
            t.barrier()?;
            Ok(t.cost_hint())
        })
        .unwrap();
        // The consensus pass makes every rank agree exactly.
        for h in &hints {
            assert!(h.alpha_s > 0.0 && h.beta_s_per_byte > 0.0);
            assert_eq!(h.alpha_s.to_bits(), hints[0].alpha_s.to_bits());
            assert_eq!(h.beta_s_per_byte.to_bits(), hints[0].beta_s_per_byte.to_bits());
        }
    }

    #[test]
    fn segment_layout_is_aligned_and_sparse_sized() {
        let (arena, total) = seg_layout(16, 4096);
        assert_eq!(arena % 64, 0);
        assert_eq!(total, arena + 16 * 15 * (RING_HDR_BYTES + 4096));
        assert_eq!(std::mem::size_of::<SegHdr>(), 64);
        assert_eq!(std::mem::size_of::<RingHdr>(), 128);
    }
}
