//! TCP-backed transport: lazy schedule-aware socket mesh, each rank
//! typically its own OS process, rendezvous via a listener map.
//!
//! ## Wire format
//!
//! Everything is little-endian `u64`-prefixed:
//!
//! ```text
//! hello  := [MAGIC u64][rank u64]           (once per connection, dialer → acceptor)
//! frame  := [tag u64][len u64][len payload bytes]
//! ```
//!
//! Every frame goes out as **one vectored write** (`writev` of the
//! 16-byte header plus the caller's *borrowed* payload, with a
//! short-write continuation loop), so a steady-state round performs one
//! syscall per frame and **zero payload copies at any size** — the old
//! path coalesced header+payload into a scratch buffer (one full memcpy)
//! for everything up to 64 KiB, and the writer-thread path memcpy'd every
//! full-duplex frame into a pooled buffer. A connection carries frames in
//! FIFO order; together with the schedule determinism of the paper that
//! is all the collectives need — no block metadata beyond the asserted
//! `tag` ever crosses the wire.
//!
//! ## Lazy mesh
//!
//! Connections are dialed on *first use*. The circulant graph of the
//! paper is `2⌈log₂p⌉`-regular, so a rank running a broadcast touches
//! `O(log p)` peers — the old eager full mesh (`p - 1` sockets per rank,
//! `O(p²)` fds in the in-process harness [`run_tcp`]) paid for `p - 1`.
//! The dial direction is deterministic — **the higher rank dials the
//! lower rank's listener** — so two ranks that first talk in the same
//! round can never attempt crossed simultaneous connects. Acceptors park
//! early arrivals from other peers in their slots while waiting. Because
//! every link is used by both of its ends in matching rounds (sendrecv
//! pairs, barrier tokens), the dialer always shows up; and because a dial
//! lands in the listener's backlog without the acceptor calling `accept`,
//! the dial-all-then-accept-all order in [`TcpTransport::warm_circulant`]
//! and the per-round link setup cannot deadlock.
//!
//! [`TcpTransport::warm_circulant`] optionally pre-connects exactly the
//! circulant neighbors (`{rank ± skipₖ}`, the same absolute edge set for
//! every broadcast root) so first rounds pay no setup latency.
//!
//! ## Persistent writers
//!
//! A full-duplex round needs send ∥ recv so that cyclic exchanges larger
//! than the socket buffers cannot deadlock. Instead of spawning a scoped
//! thread per round (~tens of µs each), every endpoint lazily gets one
//! *persistent* writer thread fed by a bounded channel. The caller hands
//! it the frame **by reference** — the tag by value plus a raw pointer to
//! the borrowed payload — and the writer performs the same single
//! vectored write as the direct path: no copy, no frame buffer.
//! The caller then reads its own inbound frame and *always* reaps the
//! write ack before returning; that ack-before-return invariant is what
//! makes the borrowed-pointer handoff sound (the payload borrow outlives
//! the write — see the safety notes on `WriteJob`) and keeps the writer
//! idle outside `sendrecv_into`, so send-only rounds may write directly
//! from the calling thread without interleaving. Writers join on drop.
//!
//! ## Idle-link reaping
//!
//! Long-lived communicators can accumulate `O(log p)` sockets per rank
//! that a later workload never touches again. [`TcpTransport::reap_idle`]
//! closes every link idle for more than a configurable number of
//! *collective epochs* and lets the lazy mesh re-dial on demand; it must
//! be called collectively at a synchronization point (see its docs).
//!
//! ## Rendezvous
//!
//! Every rank owns a listener; the *listener map* (rank → socket address)
//! is the only shared configuration. Two entry points build the map:
//!
//! * [`run_tcp`] — in-process harness: binds `p` ephemeral-port listeners
//!   up front (collision-free), then runs one rank per thread. Used by the
//!   tests and benches.
//! * [`TcpTransport::connect_base_port`] — separate-process mode: rank `r`
//!   binds `base_port + r`, so `p` processes need only agree on
//!   `(host, base_port, p)`. Used by `examples/bcast_tcp.rs`.

use super::{CostHint, FaultCtx, Payload, SendSpec, Transport, TransportError};
use std::io::{ErrorKind, IoSlice, Read, Write};
use std::net::{IpAddr, SocketAddr, TcpListener, TcpStream};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Connection hello marker: "nblkTcp1" as little-endian bytes.
pub const MAGIC: u64 = u64::from_le_bytes(*b"nblkTcp1");

/// Upper bound on a frame payload (fail fast on desynchronized streams).
pub const MAX_FRAME: u64 = 1 << 32;

fn write_u64(w: &mut impl Write, v: u64) -> std::io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

fn read_u64(r: &mut impl Read) -> std::io::Result<u64> {
    let mut buf = [0u8; 8];
    r.read_exact(&mut buf)?;
    Ok(u64::from_le_bytes(buf))
}

/// The 16-byte `[tag][len]` frame header.
fn frame_header(tag: u64, len: usize) -> [u8; 16] {
    let mut hdr = [0u8; 16];
    hdr[..8].copy_from_slice(&tag.to_le_bytes());
    hdr[8..16].copy_from_slice(&(len as u64).to_le_bytes());
    hdr
}

/// Write one `[tag][len][payload]` frame as a single vectored write
/// (header + borrowed payload, zero copies), looping until both slices
/// are fully on the wire. Short writes advance across the header/payload
/// boundary; once the header is out, plain `write` finishes the payload
/// (no point re-gathering one slice). `Interrupted` retries; a zero-length
/// write is an error (`WriteZero`), matching `write_all`.
fn write_frame_vectored(w: &mut impl Write, tag: u64, data: &[u8]) -> std::io::Result<()> {
    let hdr = frame_header(tag, data.len());
    let mut hoff = 0usize; // header bytes written
    let mut doff = 0usize; // payload bytes written
    let mut first = true;
    while hoff < hdr.len() || doff < data.len() {
        if !first {
            crate::obs::metrics::on_short_write_continuation();
        }
        first = false;
        let written = if hoff < hdr.len() {
            w.write_vectored(&[IoSlice::new(&hdr[hoff..]), IoSlice::new(&data[doff..])])
        } else {
            w.write(&data[doff..])
        };
        match written {
            Ok(0) => {
                return Err(std::io::Error::new(
                    ErrorKind::WriteZero,
                    "failed to write the whole frame",
                ))
            }
            Ok(n) => {
                let h = n.min(hdr.len() - hoff);
                hoff += h;
                doff += n - h;
                debug_assert!(doff <= data.len());
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

/// Write one `[tag][len][payload]` frame (public form for tests and
/// in-memory writers) — the same single vectored write as the transport
/// hot path, plus a flush for buffered writers.
pub fn write_frame(w: &mut impl Write, tag: u64, data: &[u8]) -> std::io::Result<()> {
    write_frame_vectored(w, tag, data)?;
    w.flush()
}

/// Read one `[tag][len][payload]` frame into a caller-owned buffer,
/// reusing its capacity. Returns the tag.
pub fn read_frame_into(r: &mut impl Read, buf: &mut Vec<u8>) -> std::io::Result<u64> {
    let tag = read_u64(r)?;
    let len = read_u64(r)?;
    if len > MAX_FRAME {
        return Err(std::io::Error::new(
            ErrorKind::InvalidData,
            format!("frame length {len} exceeds the {MAX_FRAME}-byte cap"),
        ));
    }
    // `take + read_to_end` reuses the buffer's capacity without the
    // full-payload memset that `resize + read_exact` would pay (the
    // receive path is the hot path; zeroing 64 KiB just to overwrite it
    // roughly doubles the landing cost of a block).
    buf.clear();
    let n = r.by_ref().take(len).read_to_end(buf)? as u64;
    if n != len {
        return Err(std::io::Error::new(
            ErrorKind::UnexpectedEof,
            format!("frame truncated: {n} of {len} payload bytes"),
        ));
    }
    Ok(tag)
}

/// Read one `[tag][len][payload]` frame (owning form).
pub fn read_frame(r: &mut impl Read) -> std::io::Result<(u64, Vec<u8>)> {
    let mut data = Vec::new();
    let tag = read_frame_into(r, &mut data)?;
    Ok((tag, data))
}

/// A [`Read`] adapter enforcing a *whole-frame* deadline over a
/// [`TcpStream`].
///
/// The socket's own `read_timeout` bounds each *syscall*, so a peer
/// trickling one byte per timeout window could stretch a single frame
/// arbitrarily. This wrapper checks the deadline before every read (a
/// clock read, no syscall) and, once less than half the budget remains,
/// lowers the socket timeout to the remainder — so the total blocking
/// time for one frame is bounded by ~1.5× the configured timeout while
/// the steady-state fast path pays zero extra `setsockopt` calls.
struct DeadlineRead<'a> {
    stream: &'a TcpStream,
    deadline: Instant,
    budget: Duration,
    /// Whether the socket timeout was lowered (and must be restored).
    lowered: bool,
}

impl Read for DeadlineRead<'_> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let now = Instant::now();
        if now >= self.deadline {
            return Err(std::io::Error::new(
                ErrorKind::TimedOut,
                "whole-frame recv deadline exceeded",
            ));
        }
        let remaining = self.deadline - now;
        if remaining < self.budget / 2 {
            self.stream
                .set_read_timeout(Some(remaining.max(Duration::from_millis(1))))?;
            self.lowered = true;
        }
        (&mut self.stream).read(buf)
    }
}

/// Read one frame from `stream` under a whole-frame deadline of `timeout`
/// from now, restoring the socket's configured timeout afterwards if the
/// deadline machinery lowered it.
fn read_frame_deadline(
    stream: &TcpStream,
    buf: &mut Vec<u8>,
    timeout: Duration,
) -> std::io::Result<u64> {
    let mut r = DeadlineRead {
        stream,
        deadline: Instant::now() + timeout,
        budget: timeout,
        lowered: false,
    };
    let res = read_frame_into(&mut r, buf);
    if r.lowered && res.is_ok() {
        stream.set_read_timeout(Some(timeout))?;
    }
    res
}

/// One frame handed to a persistent writer thread: the tag by value plus
/// the caller's **borrowed** payload as a raw pointer — no copy is ever
/// made of the payload on the wire path.
///
/// # Safety (why the raw pointer is sound)
///
/// The pointed-at slice is the `Payload::Bytes` borrow of an in-progress
/// [`Transport::sendrecv_into`] call, and that call *always* blocks on the
/// writer's ack before returning — even when its own read fails — so the
/// borrow strictly outlives every access the writer makes:
///
/// * the ack arrives only after the writer has finished (or abandoned)
///   the vectored write and dropped its reconstructed slice;
/// * if the ack channel reports disconnection instead, the writer thread
///   has already exited its loop (it drops the ack sender only on exit,
///   after abandoning any frame), so it can no longer touch the pointer;
/// * the job channel has capacity 1 and the ack is reaped before the next
///   job is ever submitted, so at most one frame is in flight per writer.
struct WriteJob {
    tag: u64,
    ptr: *const u8,
    len: usize,
}

// SAFETY: the pointer is only dereferenced by the writer while the
// submitting call is blocked waiting for the ack (see `WriteJob` docs).
unsafe impl Send for WriteJob {}

/// The persistent writer thread of one endpoint: receives borrowed frames
/// over a bounded channel, writes each as a single vectored write, and
/// acks the result. Dropping the `Writer` stops and joins the thread
/// (instant in every reachable state: the ack-before-return invariant
/// means the writer is idle whenever a `Writer` can be dropped).
struct Writer {
    /// `None` after shutdown begins (dropping it is what stops the thread).
    job_tx: Option<SyncSender<WriteJob>>,
    ack_rx: Receiver<std::io::Result<()>>,
    handle: Option<JoinHandle<()>>,
}

impl Drop for Writer {
    fn drop(&mut self) {
        drop(self.job_tx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// One established connection to a peer.
struct Endpoint {
    stream: TcpStream,
    writer: Option<Writer>,
    /// Collective epoch of the last round that used this link (for
    /// [`TcpTransport::reap_idle`]).
    last_used: u64,
}

/// One rank's endpoint of the lazy socket mesh: at most `2⌈log₂p⌉ + O(1)`
/// connections for the circulant collectives, established on first use
/// (or ahead of time via [`TcpTransport::warm_circulant`]).
pub struct TcpTransport {
    rank: u64,
    p: u64,
    /// Own listener, kept in non-blocking mode for lazy accepts.
    listener: TcpListener,
    /// The listener map (rank → address); own entry unused.
    addrs: Vec<SocketAddr>,
    /// `endpoints[peer]`: the connection to `peer`, once established.
    endpoints: Vec<Option<Endpoint>>,
    timeout: Duration,
    /// Current collective epoch (advanced by [`TcpTransport::reap_idle`]).
    epoch: u64,
    /// Accepted connections whose slot was still occupied: a peer that
    /// reaped its end and re-dialed before this rank reached its own
    /// (program-order-identical) reap point. The old link is quiescent by
    /// then — the redialer finished every matching round first — so the
    /// new connection parks here until our reap frees the slot, at which
    /// point [`TcpTransport::accept_until`] promotes it.
    pending_redials: Vec<(u64, TcpStream)>,
    /// `linked_before[peer]`: a link to `peer` existed at some point, so
    /// any further establishment is a *re*-establishment — what the
    /// `redials` metric counts.
    linked_before: Vec<bool>,
    /// Per-attempt TCP connect timeout used by the dial loop (see
    /// [`TcpTransport::with_connect_timeout`]).
    connect_timeout: Duration,
    /// Transport-level round counter: one per `sendrecv_into` call, so
    /// failure context can name the round a peer went silent in.
    ops: u64,
    /// When set, [`TcpTransport::reap_idle`] runs automatically with this
    /// `max_idle` after every [`Transport::barrier`] (see
    /// [`TcpTransport::with_auto_reap`]).
    auto_reap: Option<u64>,
    /// Warm-up α/β measurement; `None` until [`Transport::warm_up`] has
    /// run (the static [`CostHint::DEFAULT`] applies meanwhile).
    measured: Option<CostHint>,
}

/// Default per-attempt connect timeout of the dial loop (overridable with
/// [`TcpTransport::with_connect_timeout`]).
pub const DEFAULT_CONNECT_TIMEOUT: Duration = Duration::from_millis(250);

impl TcpTransport {
    /// Create rank `rank`'s endpoint of a `p`-rank mesh over `addrs` (the
    /// listener map; own entry is ignored), owning `listener`.
    ///
    /// No connection is established here: links are dialed/accepted on
    /// first use (higher rank dials lower), so a rank only ever holds the
    /// sockets its schedule touches — `O(log p)` for the circulant
    /// collectives instead of the old eager `p - 1`. Call
    /// [`TcpTransport::warm_circulant`] to pre-connect the circulant
    /// neighborhood eagerly.
    pub fn connect(
        rank: u64,
        p: u64,
        listener: TcpListener,
        addrs: &[SocketAddr],
        timeout: Duration,
    ) -> Result<TcpTransport, TransportError> {
        assert!(rank < p, "rank must be < p");
        if addrs.len() as u64 != p {
            return Err(TransportError::protocol(format!(
                "listener map has {} entries, need p = {p}",
                addrs.len()
            )));
        }
        listener.set_nonblocking(true)?;
        Ok(TcpTransport {
            rank,
            p,
            listener,
            addrs: addrs.to_vec(),
            endpoints: (0..p).map(|_| None).collect(),
            timeout,
            epoch: 0,
            pending_redials: Vec::new(),
            linked_before: (0..p).map(|_| false).collect(),
            connect_timeout: DEFAULT_CONNECT_TIMEOUT,
            ops: 0,
            auto_reap: None,
            measured: None,
        })
    }

    /// Override the per-attempt connect timeout used when dialing a peer's
    /// listener (default [`DEFAULT_CONNECT_TIMEOUT`], 250 ms). The dial
    /// loop keeps retrying with exponential backoff until the overall
    /// operation timeout; a larger per-attempt value helps high-latency
    /// links, a smaller one makes dead-address detection snappier.
    pub fn with_connect_timeout(mut self, connect_timeout: Duration) -> TcpTransport {
        assert!(
            connect_timeout > Duration::ZERO,
            "connect timeout must be positive"
        );
        self.connect_timeout = connect_timeout;
        self
    }

    /// Opt in to automatic idle-link reaping: after every
    /// [`Transport::barrier`] — the collective epoch boundary every rank
    /// reaches together, which is what makes the reap collective too —
    /// run [`TcpTransport::reap_idle`] with this `max_idle`. A long-lived
    /// communicator's socket budget then shrinks back to what its current
    /// workload touches without anyone calling `reap_idle` by hand.
    /// `max_idle = N` keeps links used within the last `N` barrier
    /// epochs; the barrier's own dissemination links are used *every*
    /// epoch, so any `max_idle ≥ 1` retains them.
    pub fn with_auto_reap(mut self, max_idle: u64) -> TcpTransport {
        self.auto_reap = Some(max_idle);
        self
    }

    /// Note that the link to `peer` is (re-)established, bumping the
    /// `redials` metric when it existed before.
    fn note_linked(&mut self, peer: u64) {
        if self.linked_before[peer as usize] {
            crate::obs::metrics::on_redial();
        }
        self.linked_before[peer as usize] = true;
    }

    /// Separate-process rendezvous: rank `r` listens on
    /// `host:(base_port + r)`; the listener map is implied by
    /// `(host, base_port)`. All `p` processes call this with the same
    /// parameters and their own `rank`.
    pub fn connect_base_port(
        rank: u64,
        p: u64,
        host: IpAddr,
        base_port: u16,
        timeout: Duration,
    ) -> Result<TcpTransport, TransportError> {
        let mut addrs = Vec::with_capacity(p as usize);
        for r in 0..p {
            let port = u16::try_from(r)
                .ok()
                .and_then(|r16| base_port.checked_add(r16))
                .ok_or_else(|| {
                    TransportError::protocol(format!(
                        "port range {base_port}..{base_port}+{p} exceeds 65535"
                    ))
                })?;
            addrs.push(SocketAddr::new(host, port));
        }
        let listener = TcpListener::bind(addrs[rank as usize])?;
        TcpTransport::connect(rank, p, listener, &addrs, timeout)
    }

    /// Number of peer connections currently established (the lazy-mesh
    /// tests assert this stays `O(log p)` through a broadcast).
    pub fn established_connections(&self) -> usize {
        self.endpoints.iter().filter(|e| e.is_some()).count()
    }

    /// Advance the collective epoch and close every link that was idle for
    /// more than `max_idle` epochs, returning the number closed. Closed
    /// links re-establish on demand through the ordinary lazy dial path,
    /// so a long-lived communicator's socket budget shrinks back to what
    /// its current workload actually touches (`max_idle = 0` closes every
    /// link; `max_idle = N` keeps links used within the last `N` calls).
    ///
    /// Like every connection-setup path this must be called
    /// **collectively and symmetrically**: every rank calls it at the same
    /// program point with the same `max_idle`, immediately after a
    /// synchronization ([`Transport::barrier`] or the end of a collective)
    /// and before any further communication. Both ends of a link observe
    /// identical usage epochs (every use is a matching send/recv pair), so
    /// they always agree on which links die — a one-sided close would
    /// instead strand the peer's half-open socket and poison its next
    /// accept.
    pub fn reap_idle(&mut self, max_idle: u64) -> usize {
        self.epoch += 1;
        let epoch = self.epoch;
        let mut closed = 0usize;
        for slot in self.endpoints.iter_mut() {
            if slot.as_ref().is_some_and(|ep| epoch - ep.last_used > max_idle) {
                // Dropping the endpoint joins its writer (idle by the
                // ack-before-return invariant) and closes the socket.
                *slot = None;
                closed += 1;
            }
        }
        crate::obs::metrics::on_reaped(closed as u64);
        closed
    }

    /// Drop **every** established link, returning the number closed — the
    /// recovery step after a failed collective.
    ///
    /// When a round fails (a peer died, a read timed out), frames may
    /// still be in flight on links *between survivors*: a rank that
    /// errored out mid-collective never drained them, so its streams are
    /// desynchronized even toward healthy peers. Surviving ranks call
    /// `reset_links` collectively (same program point on every rank)
    /// and let the lazy mesh re-dial fresh connections on demand — the
    /// bounded exponential-backoff dial loop plus the redial-parking in
    /// `accept_until` (a peer that resets and re-dials before this rank
    /// resets parks its fresh connection until the slot frees) make the
    /// re-establishment race-free. Parked redials are *kept*: they are
    /// new, clean connections, exactly what recovery promotes.
    pub fn reset_links(&mut self) -> usize {
        let mut closed = 0usize;
        for slot in self.endpoints.iter_mut() {
            if slot.take().is_some() {
                closed += 1;
            }
        }
        crate::obs::metrics::on_reaped(closed as u64);
        closed
    }

    /// Eagerly connect exactly the circulant neighborhood `{rank ± skipₖ}`
    /// (at most `2⌈log₂p⌉` peers — independent of the broadcast root,
    /// since relative-rank arithmetic cancels the root shift). Returns the
    /// neighbor count. Dials first, accepts second: dials never block on
    /// the acceptor (listener backlog), so all ranks can warm concurrently.
    pub fn warm_circulant(&mut self) -> Result<usize, TransportError> {
        if self.p == 1 {
            return Ok(0);
        }
        let skips = crate::sched::Skips::new(self.p);
        let mut peers: Vec<u64> = Vec::new();
        for k in 0..skips.q() {
            for peer in [skips.to_proc(self.rank, k), skips.from_proc(self.rank, k)] {
                if !peers.contains(&peer) {
                    peers.push(peer);
                }
            }
        }
        self.warm_list(&peers)
    }

    /// Establish links to every listed peer not yet connected (duplicates,
    /// the own rank and out-of-range entries are skipped; already-warm
    /// links are free). Returns the number of distinct peers requested.
    /// Must be called collectively with symmetric peer sets — see
    /// [`Transport::warm_peers`] — and uses the same deadlock-free
    /// dial-all-then-accept-all order as [`TcpTransport::warm_circulant`].
    fn warm_list(&mut self, peers: &[u64]) -> Result<usize, TransportError> {
        let mut wanted: Vec<u64> = Vec::new();
        for &peer in peers {
            if peer != self.rank && peer < self.p && !wanted.contains(&peer) {
                wanted.push(peer);
            }
        }
        let deadline = Instant::now() + self.timeout;
        for &peer in &wanted {
            if peer < self.rank {
                self.dial(peer, deadline)?;
            }
        }
        for &peer in &wanted {
            if peer > self.rank {
                self.accept_until(peer, deadline)?;
            }
        }
        Ok(wanted.len())
    }

    fn check_peer(&self, peer: u64) -> Result<(), TransportError> {
        if peer >= self.p || peer == self.rank {
            return Err(TransportError::Collective(format!(
                "rank {}: invalid peer {peer} (p = {})",
                self.rank, self.p
            )));
        }
        Ok(())
    }

    /// Establish the (up to two) links this round needs. Dial phase first,
    /// accept phase second — see the module docs for why this ordering is
    /// deadlock-free.
    fn ensure_links(
        &mut self,
        a: Option<u64>,
        b: Option<u64>,
    ) -> Result<(), TransportError> {
        if [a, b]
            .into_iter()
            .flatten()
            .all(|peer| self.endpoints[peer as usize].is_some())
        {
            return Ok(());
        }
        let deadline = Instant::now() + self.timeout;
        for peer in [a, b].into_iter().flatten() {
            if peer < self.rank && self.endpoints[peer as usize].is_none() {
                self.dial(peer, deadline)?;
            }
        }
        for peer in [a, b].into_iter().flatten() {
            if peer > self.rank && self.endpoints[peer as usize].is_none() {
                self.accept_until(peer, deadline)?;
            }
        }
        Ok(())
    }

    /// Dial `peer` (a lower rank), retrying with exponential backoff until
    /// the deadline — its listener may not be bound yet in separate-process
    /// mode, or the link is being re-established after a failure.
    fn dial(&mut self, peer: u64, deadline: Instant) -> Result<(), TransportError> {
        debug_assert!(peer < self.rank, "dial direction: higher dials lower");
        if self.endpoints[peer as usize].is_some() {
            return Ok(());
        }
        let addr = self.addrs[peer as usize];
        // Bounded re-dial: per-attempt connect timeout (configurable via
        // `with_connect_timeout`), exponential backoff between attempts
        // (1 ms doubling to a 100 ms cap), overall bound = the deadline.
        let mut backoff = Duration::from_millis(1);
        const BACKOFF_CAP: Duration = Duration::from_millis(100);
        let stream = loop {
            match TcpStream::connect_timeout(&addr, self.connect_timeout) {
                Ok(s) => break s,
                Err(e) => {
                    if Instant::now() >= deadline {
                        return Err(TransportError::timeout_at(
                            format!("rank {}: dialing rank {peer} at {addr}: {e}", self.rank),
                            FaultCtx::peer(peer)
                                .with_round(self.ops)
                                .with_epoch(self.epoch),
                        ));
                    }
                    std::thread::sleep(backoff.min(deadline.saturating_duration_since(
                        Instant::now(),
                    )));
                    backoff = (backoff * 2).min(BACKOFF_CAP);
                }
            }
        };
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(self.timeout))?;
        stream.set_write_timeout(Some(self.timeout))?;
        let mut s = stream;
        write_u64(&mut s, MAGIC)?;
        write_u64(&mut s, self.rank)?;
        self.endpoints[peer as usize] = Some(Endpoint {
            stream: s,
            writer: None,
            last_used: self.epoch,
        });
        self.note_linked(peer);
        Ok(())
    }

    /// Accept connections (parking early arrivals from other peers in
    /// their slots) until the one from `peer` — a higher rank, by the dial
    /// rule — is established.
    fn accept_until(&mut self, peer: u64, deadline: Instant) -> Result<(), TransportError> {
        debug_assert!(peer > self.rank, "dial direction: higher dials lower");
        while self.endpoints[peer as usize].is_none() {
            // A parked redial for this (now free) slot wins over the
            // listener backlog: it arrived first, and per-pair FIFO must
            // hold across the reconnect.
            if let Some(pos) = self.pending_redials.iter().position(|&(r, _)| r == peer) {
                let (_, s) = self.pending_redials.swap_remove(pos);
                self.endpoints[peer as usize] = Some(Endpoint {
                    stream: s,
                    writer: None,
                    last_used: self.epoch,
                });
                self.note_linked(peer);
                return Ok(());
            }
            match self.listener.accept() {
                Ok((stream, _)) => {
                    stream.set_nonblocking(false)?;
                    stream.set_nodelay(true)?;
                    stream.set_read_timeout(Some(self.timeout))?;
                    stream.set_write_timeout(Some(self.timeout))?;
                    let mut s = stream;
                    let magic = read_u64(&mut s)?;
                    if magic != MAGIC {
                        return Err(TransportError::protocol(format!(
                            "rank {}: bad hello magic {magic:#018x}",
                            self.rank
                        )));
                    }
                    let from = read_u64(&mut s)?;
                    if from <= self.rank || from >= self.p {
                        return Err(TransportError::protocol(format!(
                            "rank {}: hello from unexpected rank {from}",
                            self.rank
                        )));
                    }
                    if self.endpoints[from as usize].is_some() {
                        // The peer reaped its end and re-dialed before this
                        // rank reached its own reap point (the reap contract
                        // guarantees the old link is quiescent and will be
                        // closed here too): park the new connection until
                        // the slot frees up. Two parked hellos from one rank
                        // would mean a genuinely broken peer.
                        if self.pending_redials.iter().any(|&(r, _)| r == from) {
                            return Err(TransportError::protocol(format!(
                                "rank {}: duplicate connection from rank {from}",
                                self.rank
                            )));
                        }
                        self.pending_redials.push((from, s));
                        continue;
                    }
                    self.endpoints[from as usize] = Some(Endpoint {
                        stream: s,
                        writer: None,
                        last_used: self.epoch,
                    });
                    self.note_linked(from);
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        return Err(TransportError::timeout_at(
                            format!(
                                "rank {}: waited {:?} for rank {peer} to dial",
                                self.rank, self.timeout
                            ),
                            FaultCtx::peer(peer)
                                .with_round(self.ops)
                                .with_epoch(self.epoch),
                        ));
                    }
                    std::thread::sleep(Duration::from_millis(2));
                }
                Err(e) => return Err(e.into()),
            }
        }
        Ok(())
    }

    /// Spawn the persistent writer thread for `peer`'s endpoint if it does
    /// not exist yet. The endpoint must be established.
    fn ensure_writer(&mut self, peer: u64) -> Result<(), TransportError> {
        let rank = self.rank;
        let ctx = FaultCtx::peer(peer).with_round(self.ops).with_epoch(self.epoch);
        let ep = self.endpoints[peer as usize]
            .as_mut()
            .expect("endpoint established before ensure_writer");
        if ep.writer.is_some() {
            return Ok(());
        }
        let stream = ep.stream.try_clone().map_err(|e| {
            TransportError::io_at(format!("rank {rank}: cloning stream to {peer}: {e}"), ctx)
        })?;
        let (job_tx, job_rx) = sync_channel::<WriteJob>(1);
        let (ack_tx, ack_rx) = sync_channel::<std::io::Result<()>>(1);
        let handle = std::thread::Builder::new()
            .name(format!("nblk-writer-{rank}-{peer}"))
            .spawn(move || {
                let mut stream = stream;
                while let Ok(job) = job_rx.recv() {
                    // SAFETY: the submitting `sendrecv_into` call keeps its
                    // payload borrow alive until it has reaped the ack for
                    // this very job (see the `WriteJob` safety notes), so
                    // the pointed-at bytes are valid for the whole write.
                    let data = unsafe { std::slice::from_raw_parts(job.ptr, job.len) };
                    let res = write_frame_vectored(&mut stream, job.tag, data);
                    if ack_tx.send(res).is_err() {
                        break;
                    }
                }
            })
            .map_err(|e| {
                TransportError::io_at(
                    format!("rank {rank}: spawning writer for {peer}: {e}"),
                    ctx,
                )
            })?;
        ep.writer = Some(Writer {
            job_tx: Some(job_tx),
            ack_rx,
            handle: Some(handle),
        });
        Ok(())
    }

    /// Write one frame to `to` from the calling thread: a single vectored
    /// write of header + borrowed payload — one syscall, zero copies at
    /// any size.
    ///
    /// Safe next to a persistent writer because of the ack-before-return
    /// invariant: outside `sendrecv_into` the writer holds no frame.
    fn write_direct(&mut self, to: u64, tag: u64, data: &[u8]) -> Result<(), TransportError> {
        let rank = self.rank;
        let epoch = self.epoch;
        let res = {
            let ep = self.endpoints[to as usize]
                .as_mut()
                .expect("endpoint established before write_direct");
            ep.last_used = epoch;
            write_frame_vectored(&mut ep.stream, tag, data)
        };
        res.map_err(|e| {
            // A failed write may have emitted part of the frame: the
            // stream is desynchronized, never reuse it.
            self.endpoints[to as usize] = None;
            TransportError::io_at(
                format!("rank {rank}: writing to {to}: {e}"),
                FaultCtx::peer(to).with_round(self.ops).with_epoch(epoch),
            )
        })
    }

    /// The real bytes of an outgoing payload, or a protocol error: the
    /// wire exists to move bytes, so size-only (virtual) payloads are
    /// rejected — cost sweeps belong on the sim/cost backend.
    fn payload_bytes<'a>(&self, data: Payload<'a>) -> Result<&'a [u8], TransportError> {
        data.bytes().ok_or_else(|| {
            TransportError::protocol(format!(
                "rank {}: virtual payload ({} bytes) on the tcp backend \
                 — use the sim/cost backend for size-only sweeps",
                self.rank,
                data.len()
            ))
        })
    }

    /// Record a failed read and map its error: a frame may have been
    /// half-consumed, so the inbound stream is desynchronized — drop the
    /// endpoint so it can never be reused.
    fn poison_read(&mut self, from: u64, e: std::io::Error) -> TransportError {
        self.endpoints[from as usize] = None;
        let ctx = FaultCtx::peer(from)
            .with_round(self.ops)
            .with_epoch(self.epoch);
        if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut {
            TransportError::timeout_at(
                format!(
                    "rank {}: waited {:?} for a block from {from}: {e}",
                    self.rank, self.timeout
                ),
                ctx,
            )
        } else {
            TransportError::io_at(
                format!("rank {}: reading from {from}: {e}", self.rank),
                ctx,
            )
        }
    }
}

impl Transport for TcpTransport {
    fn rank(&self) -> u64 {
        self.rank
    }

    fn size(&self) -> u64 {
        self.p
    }

    fn warm_up(&mut self) -> Result<(), TransportError> {
        // Pre-dialing is an optimization — links dial lazily on first use
        // — so failures downgrade to a warning instead of killing the run.
        if let Err(e) = self.warm_circulant() {
            super::warn_warm_up(self.rank(), "pre-dial", &e);
            return Ok(());
        }
        // One-time α/β probe over the freshly-warmed ring links; the
        // consensus pass inside makes every rank adopt the same fit, so
        // hint-driven resolution stays rank-uniform. A timed-out or
        // faulted probe keeps the static hint.
        if self.measured.is_none() {
            match super::measure_link_hint(self) {
                Ok(h) => self.measured = h,
                Err(e) => super::warn_warm_up(self.rank(), "α/β probe", &e),
            }
        }
        Ok(())
    }

    fn warm_peers(&mut self, peers: &[u64]) -> Result<(), TransportError> {
        self.warm_list(peers).map(|_| ())
    }

    fn sendrecv_into(
        &mut self,
        send: Option<SendSpec<'_>>,
        recv_from: Option<u64>,
        recv_buf: &mut Vec<u8>,
    ) -> Result<Option<u64>, TransportError> {
        #[cfg(feature = "obs")]
        let t0 = crate::obs::now_ns();
        #[cfg(feature = "obs")]
        let sent_info = send.map(|s| (s.to, s.tag, s.data.len()));
        let res = self.round_impl(send, recv_from, recv_buf);
        #[cfg(feature = "obs")]
        if let Ok(got) = &res {
            if let Some((_, _, bytes)) = sent_info {
                crate::obs::metrics::on_send(bytes);
            }
            let recv_info = got.map(|tag| {
                (
                    recv_from.expect("got implies recv_from"),
                    tag,
                    recv_buf.len() as u64,
                )
            });
            if let Some((_, _, bytes)) = recv_info {
                crate::obs::metrics::on_recv(bytes);
            }
            crate::obs::record_round(sent_info, recv_info, t0);
        }
        res
    }

    fn cost_hint(&self) -> CostHint {
        self.measured.unwrap_or(CostHint::DEFAULT)
    }

    fn barrier(&mut self) -> Result<(), TransportError> {
        // FIFO per pair keeps barrier tokens behind any in-flight data;
        // the token links are established lazily like any other link.
        super::dissemination_barrier(self)?;
        // The barrier is the collective epoch boundary: every rank is
        // here together, so an opted-in reap is itself collective.
        if let Some(max_idle) = self.auto_reap {
            self.reap_idle(max_idle);
        }
        Ok(())
    }
}

impl TcpTransport {
    /// The uninstrumented round body behind [`Transport::sendrecv_into`].
    fn round_impl(
        &mut self,
        send: Option<SendSpec<'_>>,
        recv_from: Option<u64>,
        recv_buf: &mut Vec<u8>,
    ) -> Result<Option<u64>, TransportError> {
        self.ops += 1;
        match (send, recv_from) {
            (None, None) => Ok(None),
            (Some(s), None) => {
                self.check_peer(s.to)?;
                let data = self.payload_bytes(s.data)?;
                self.ensure_links(Some(s.to), None)?;
                self.write_direct(s.to, s.tag, data)?;
                Ok(None)
            }
            (None, Some(from)) => {
                self.check_peer(from)?;
                self.ensure_links(Some(from), None)?;
                let epoch = self.epoch;
                let timeout = self.timeout;
                let got = {
                    let ep = self.endpoints[from as usize]
                        .as_mut()
                        .expect("link established above");
                    ep.last_used = epoch;
                    read_frame_deadline(&ep.stream, recv_buf, timeout)
                };
                got.map(Some).map_err(|e| self.poison_read(from, e))
            }
            (Some(s), Some(from)) => {
                // Send ∥ recv, possibly with the same peer: the persistent
                // writer thread carries the outgoing frame while this
                // thread reads, so cyclic rounds with payloads larger than
                // the socket buffers cannot deadlock. The frame is handed
                // over as tag-by-value + borrowed payload pointer — the
                // writer performs the same single vectored write as the
                // direct path, with zero copies (see `WriteJob`).
                self.check_peer(s.to)?;
                self.check_peer(from)?;
                let data = self.payload_bytes(s.data)?;
                self.ensure_links(Some(s.to), Some(from))?;
                self.ensure_writer(s.to)?;
                let epoch = self.epoch;
                if let Some(ep) = self.endpoints[s.to as usize].as_mut() {
                    ep.last_used = epoch;
                }
                if let Some(ep) = self.endpoints[from as usize].as_mut() {
                    ep.last_used = epoch;
                }
                let rank = self.rank;
                let (got, ack) = {
                    let writer = self.endpoints[s.to as usize]
                        .as_ref()
                        .expect("link established above")
                        .writer
                        .as_ref()
                        .expect("writer spawned above");
                    writer
                        .job_tx
                        .as_ref()
                        .expect("writer alive")
                        .send(WriteJob {
                            tag: s.tag,
                            ptr: data.as_ptr(),
                            len: data.len(),
                        })
                        .map_err(|_| {
                            TransportError::io_at(
                                format!("rank {rank}: writer thread for {} is gone", s.to),
                                FaultCtx::peer(s.to).with_round(self.ops).with_epoch(epoch),
                            )
                        })?;
                    let reader: &TcpStream = &self.endpoints[from as usize]
                        .as_ref()
                        .expect("link established above")
                        .stream;
                    let got = read_frame_deadline(reader, recv_buf, self.timeout);
                    // Always reap the ack, even when the read failed: the
                    // ack-before-return invariant is what keeps direct
                    // writes from interleaving with the writer thread AND
                    // what keeps the borrowed payload pointer valid for
                    // the writer's whole write (`data` lives until this
                    // function returns). Block without a cap, exactly like
                    // the old scoped-thread join did: a *stalled* write
                    // fails on its own via the stream's write timeout, so
                    // the ack always arrives, while a slow-but-progressing
                    // large write is allowed to finish instead of
                    // poisoning the link.
                    let ack = writer.ack_rx.recv();
                    (got, ack)
                };
                match ack {
                    Ok(wres) => {
                        wres.map_err(|e| {
                            // Possibly-partial write: the outbound stream
                            // is desynchronized, never reuse it.
                            self.endpoints[s.to as usize] = None;
                            TransportError::io_at(
                                format!("rank {rank}: writing to {}: {e}", s.to),
                                FaultCtx::peer(s.to).with_round(self.ops).with_epoch(epoch),
                            )
                        })?;
                    }
                    Err(_) => {
                        // The writer died without acking; it exited its
                        // loop first (so it no longer touches the payload
                        // pointer), but whether the frame made it out —
                        // fully or partially — is unknowable, so the
                        // stream is desynchronized: poison the endpoint.
                        // The link is NOT recoverable — the round has
                        // already failed for both sides, and any further
                        // use of this peer errors instead of corrupting
                        // the stream.
                        self.endpoints[s.to as usize] = None;
                        return Err(TransportError::io_at(
                            format!("rank {rank}: writer thread for {} died", s.to),
                            FaultCtx::peer(s.to).with_round(self.ops).with_epoch(epoch),
                        ));
                    }
                }
                got.map(Some).map_err(|e| self.poison_read(from, e))
            }
        }
    }
}

/// Bind `p` ephemeral-port listeners on localhost and return them with the
/// listener map (collision-free in-process rendezvous).
pub fn bind_mesh(p: u64) -> Result<(Vec<TcpListener>, Vec<SocketAddr>), TransportError> {
    let mut listeners = Vec::with_capacity(p as usize);
    let mut addrs = Vec::with_capacity(p as usize);
    for _ in 0..p {
        let l = TcpListener::bind("127.0.0.1:0")?;
        addrs.push(l.local_addr()?);
        listeners.push(l);
    }
    Ok((listeners, addrs))
}

/// Run `f` as an SPMD program over real localhost sockets, one rank per
/// thread (the wire path is identical to the separate-process mode; only
/// the rendezvous differs). Connections are lazy, so the in-process fd
/// footprint is `O(p log p)` for the circulant collectives (~3k fds at
/// `p = 128`, vs ~16k stream ends for the old eager `O(p²)` mesh) —
/// which is what lets `run_tcp` handle `p` in the hundreds within
/// ordinary fd limits (the classic 1024 soft default still needs
/// raising past p ≈ 48; eager meshing broke there already at p ≈ 23).
/// Returns the per-rank results (index = rank).
pub fn run_tcp<R, F>(p: u64, timeout: Duration, f: F) -> Result<Vec<R>, TransportError>
where
    R: Send,
    F: Fn(TcpTransport) -> Result<R, TransportError> + Sync,
{
    assert!(p >= 1, "need at least one rank");
    let (listeners, addrs) = bind_mesh(p)?;
    let mut results: Vec<Option<Result<R, TransportError>>> = (0..p).map(|_| None).collect();
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(p as usize);
        for (rank, listener) in listeners.into_iter().enumerate() {
            let f = &f;
            let addrs = &addrs;
            handles.push(s.spawn(move || {
                let t = TcpTransport::connect(rank as u64, p, listener, addrs, timeout)?;
                f(t)
            }));
        }
        for (rank, h) in handles.into_iter().enumerate() {
            results[rank] = Some(h.join().unwrap_or_else(|_| {
                Err(TransportError::Collective(format!("rank {rank} panicked")))
            }));
        }
    });
    super::drain_results(results, |e| {
        matches!(
            e,
            TransportError::Timeout { .. } | TransportError::Io { .. }
        )
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, 42, b"hello blocks").unwrap();
        write_frame(&mut buf, 7, b"").unwrap();
        let mut r = &buf[..];
        assert_eq!(read_frame(&mut r).unwrap(), (42, b"hello blocks".to_vec()));
        assert_eq!(read_frame(&mut r).unwrap(), (7, Vec::new()));
        assert!(r.is_empty());
    }

    #[test]
    fn frame_into_reuses_capacity() {
        let mut wire = Vec::new();
        write_frame(&mut wire, 1, &[9u8; 300]).unwrap();
        write_frame(&mut wire, 2, &[8u8; 100]).unwrap();
        let mut r = &wire[..];
        let mut buf = Vec::new();
        assert_eq!(read_frame_into(&mut r, &mut buf).unwrap(), 1);
        assert_eq!(buf.len(), 300);
        let cap = buf.capacity();
        let ptr = buf.as_ptr();
        assert_eq!(read_frame_into(&mut r, &mut buf).unwrap(), 2);
        assert_eq!(buf.len(), 100);
        assert!(buf.iter().all(|&b| b == 8));
        assert_eq!(buf.capacity(), cap, "no reallocation on a smaller frame");
        assert_eq!(buf.as_ptr(), ptr, "buffer storage is stable");
    }

    #[test]
    fn frame_cap_rejected() {
        let mut buf = Vec::new();
        write_u64(&mut buf, 1).unwrap();
        write_u64(&mut buf, MAX_FRAME + 1).unwrap();
        let mut r = &buf[..];
        assert_eq!(
            read_frame(&mut r).unwrap_err().kind(),
            ErrorKind::InvalidData
        );
    }

    #[test]
    fn vectored_frame_layout_is_header_then_payload() {
        // The vectored writer must produce exactly [tag][len][payload],
        // byte-identical to the documented wire format, including the
        // empty-payload edge.
        let mut a = Vec::new();
        write_frame(&mut a, 5, b"payload").unwrap();
        let mut want = Vec::new();
        want.extend_from_slice(&5u64.to_le_bytes());
        want.extend_from_slice(&(b"payload".len() as u64).to_le_bytes());
        want.extend_from_slice(b"payload");
        assert_eq!(a, want);
    }

    /// A writer that accepts at most `cap` bytes per call: exercises the
    /// short-write continuation across the header/payload boundary.
    struct Trickle {
        out: Vec<u8>,
        cap: usize,
    }

    impl Write for Trickle {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            let n = buf.len().min(self.cap);
            self.out.extend_from_slice(&buf[..n]);
            Ok(n)
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn vectored_write_survives_short_writes() {
        for cap in [1usize, 3, 7, 16, 17, 64] {
            let mut w = Trickle {
                out: Vec::new(),
                cap,
            };
            let payload: Vec<u8> = (0..100u8).collect();
            write_frame(&mut w, 42, &payload).unwrap();
            let mut r = &w.out[..];
            assert_eq!(read_frame(&mut r).unwrap(), (42, payload.clone()), "cap={cap}");
            assert!(r.is_empty());
        }
    }

    #[test]
    fn mesh_pairwise_exchange() {
        let results = run_tcp(4, Duration::from_secs(20), |mut t| {
            let partner = t.rank() ^ 1;
            let payload = vec![t.rank() as u8; 9];
            let got = t.sendrecv(
                Some(SendSpec {
                    to: partner,
                    tag: t.rank(),
                    data: Payload::Bytes(&payload),
                }),
                Some(partner),
            )?;
            let msg = got.expect("scheduled receive");
            t.barrier()?;
            Ok(msg)
        })
        .unwrap();
        for (r, msg) in results.iter().enumerate() {
            let partner = (r as u64) ^ 1;
            assert_eq!(msg.tag, partner);
            assert_eq!(msg.data, vec![partner as u8; 9]);
        }
    }

    #[test]
    fn large_cyclic_round_does_not_deadlock() {
        // Every rank sends 1 MiB around a ring while receiving 1 MiB —
        // larger than default socket buffers, so this deadlocks unless
        // send ∥ recv is genuinely concurrent.
        let p = 3u64;
        let m = 1 << 20;
        let results = run_tcp(p, Duration::from_secs(30), |mut t| {
            let r = t.rank();
            let payload = vec![r as u8; m];
            let got = t.sendrecv(
                Some(SendSpec {
                    to: (r + 1) % p,
                    tag: r,
                    data: Payload::Bytes(&payload),
                }),
                Some((r + p - 1) % p),
            )?;
            Ok(got.expect("scheduled receive"))
        })
        .unwrap();
        for (r, msg) in results.iter().enumerate() {
            let prev = ((r as u64 + p - 1) % p) as u8;
            assert_eq!(msg.tag, prev as u64);
            assert_eq!(msg.data.len(), m);
            assert!(msg.data.iter().all(|&b| b == prev));
        }
    }

    #[test]
    fn lazy_mesh_connects_only_used_links() {
        // A 2-exchange among ranks {0,1} of a 6-rank mesh: the other four
        // ranks never open a socket, the active pair opens exactly one.
        let counts = run_tcp(6, Duration::from_secs(20), |mut t| {
            if t.rank() < 2 {
                let partner = t.rank() ^ 1;
                let payload = [t.rank() as u8; 4];
                let got = t.sendrecv(
                    Some(SendSpec {
                        to: partner,
                        tag: t.rank(),
                        data: Payload::Bytes(&payload),
                    }),
                    Some(partner),
                )?;
                assert_eq!(got.expect("scheduled receive").tag, partner);
            }
            Ok(t.established_connections())
        })
        .unwrap();
        assert_eq!(counts, vec![1, 1, 0, 0, 0, 0]);
    }

    #[test]
    fn reap_idle_shrinks_socket_budget_and_relinks_on_demand() {
        use crate::collectives::generic::bcast_circulant;
        let m = 40_000u64;
        let msg: Vec<u8> = (0..m).map(|i| ((i * 17 + 3) % 251) as u8).collect();
        let budgets = run_tcp(8, Duration::from_secs(30), |mut t| {
            let data = if t.rank() == 0 { Some(&msg[..]) } else { None };
            let out = bcast_circulant(&mut t, 0, 3, m, data)?;
            assert_eq!(out, msg);
            t.barrier()?;
            let before = t.established_connections();
            assert!(before > 0, "broadcast must have opened links");
            // Collective reap right after the barrier: every link was last
            // used in the current epoch, so max_idle = 0 closes them all.
            let closed = t.reap_idle(0);
            assert_eq!(closed, before, "every idle link must close");
            assert_eq!(t.established_connections(), 0);
            // Reconnect-on-demand through the ordinary lazy dial path.
            let out = bcast_circulant(&mut t, 0, 3, m, data)?;
            assert_eq!(out, msg);
            t.barrier()?;
            // A reap that keeps the last epoch's links leaves them alone.
            let kept = t.established_connections();
            assert_eq!(t.reap_idle(1), 0);
            assert_eq!(t.established_connections(), kept);
            Ok((before, kept))
        })
        .unwrap();
        for (r, &(before, kept)) in budgets.iter().enumerate() {
            assert!(kept > 0 && before > 0, "rank {r}: links must re-establish");
        }
    }

    #[test]
    fn warm_circulant_connects_neighbors_symmetrically() {
        let counts = run_tcp(9, Duration::from_secs(20), |mut t| {
            let n = t.warm_circulant()?;
            assert_eq!(t.established_connections(), n);
            t.barrier()?;
            Ok(n)
        })
        .unwrap();
        let q = crate::sched::ceil_log2(9);
        for (r, &n) in counts.iter().enumerate() {
            assert!(n <= 2 * q, "rank {r}: {n} neighbors > 2q = {}", 2 * q);
            assert!(n >= 2, "rank {r}: suspiciously few neighbors ({n})");
        }
    }
}
