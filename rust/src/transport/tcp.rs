//! TCP-backed transport: one socket per directed pair, each rank typically
//! its own OS process, rendezvous via a listener map.
//!
//! ## Wire format
//!
//! Everything is little-endian `u64`-prefixed:
//!
//! ```text
//! hello  := [MAGIC u64][rank u64]           (once per connection, dialer → acceptor)
//! frame  := [tag u64][len u64][len payload bytes]
//! ```
//!
//! A connection carries frames in FIFO order; together with the schedule
//! determinism of the paper that is all the collectives need — no block
//! metadata beyond the asserted `tag` ever crosses the wire.
//!
//! ## Rendezvous
//!
//! Every rank owns a listener; the *listener map* (rank → socket address)
//! is the only shared configuration. Rank `r` dials every rank below it
//! (retrying until the peer's listener is up) and accepts connections from
//! every rank above it, identified by the hello frame. Two entry points
//! build the map:
//!
//! * [`run_tcp`] — in-process harness: binds `p` ephemeral-port listeners
//!   up front (collision-free), then runs one rank per thread. Used by the
//!   tests and benches.
//! * [`TcpTransport::connect_base_port`] — separate-process mode: rank `r`
//!   binds `base_port + r`, so `p` processes need only agree on
//!   `(host, base_port, p)`. Used by `examples/bcast_tcp.rs`.

use super::{SendSpec, Transport, TransportError, WireMsg};
use std::io::{ErrorKind, Read, Write};
use std::net::{IpAddr, SocketAddr, TcpListener, TcpStream};
use std::time::{Duration, Instant};

/// Connection hello marker: "nblkTcp1" as little-endian bytes.
pub const MAGIC: u64 = u64::from_le_bytes(*b"nblkTcp1");

/// Upper bound on a frame payload (fail fast on desynchronized streams).
pub const MAX_FRAME: u64 = 1 << 32;

fn write_u64(w: &mut impl Write, v: u64) -> std::io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

fn read_u64(r: &mut impl Read) -> std::io::Result<u64> {
    let mut buf = [0u8; 8];
    r.read_exact(&mut buf)?;
    Ok(u64::from_le_bytes(buf))
}

/// Write one `[tag][len][payload]` frame.
pub fn write_frame(w: &mut impl Write, tag: u64, data: &[u8]) -> std::io::Result<()> {
    write_u64(w, tag)?;
    write_u64(w, data.len() as u64)?;
    w.write_all(data)?;
    w.flush()
}

/// Read one `[tag][len][payload]` frame.
pub fn read_frame(r: &mut impl Read) -> std::io::Result<(u64, Vec<u8>)> {
    let tag = read_u64(r)?;
    let len = read_u64(r)?;
    if len > MAX_FRAME {
        return Err(std::io::Error::new(
            ErrorKind::InvalidData,
            format!("frame length {len} exceeds the {MAX_FRAME}-byte cap"),
        ));
    }
    let mut data = vec![0u8; len as usize];
    r.read_exact(&mut data)?;
    Ok((tag, data))
}

/// One rank's endpoint of the socket mesh.
///
/// The mesh is eager and fully connected: `p - 1` sockets per rank. That
/// is the simplest correct rendezvous, but it makes the *in-process*
/// harness [`run_tcp`] hold `O(p²)` file descriptors — fine at test/bench
/// scale (`p ≤ 16`), but watch `ulimit -n` beyond that. The circulant
/// schedules only ever touch `2⌈log₂p⌉` neighbors per rank, so a lazy
/// variant is a known follow-up (see ROADMAP).
pub struct TcpTransport {
    rank: u64,
    p: u64,
    /// `streams[peer]`: the connection to `peer` (`None` only at `rank`).
    streams: Vec<Option<TcpStream>>,
    timeout: Duration,
}

impl TcpTransport {
    /// Establish the full mesh for `rank` out of `p`: dial every lower
    /// rank through `addrs` (the listener map; own entry is ignored),
    /// accept every higher rank on `listener`. Returns once all `p - 1`
    /// connections are up, or errors at `timeout`.
    pub fn connect(
        rank: u64,
        p: u64,
        listener: TcpListener,
        addrs: &[SocketAddr],
        timeout: Duration,
    ) -> Result<TcpTransport, TransportError> {
        assert!(rank < p, "rank must be < p");
        if addrs.len() as u64 != p {
            return Err(TransportError::Protocol(format!(
                "listener map has {} entries, need p = {p}",
                addrs.len()
            )));
        }
        let deadline = Instant::now() + timeout;
        let pu = p as usize;
        let mut streams: Vec<Option<TcpStream>> = (0..pu).map(|_| None).collect();
        // Dial phase: lower ranks. Their listeners may not be up yet —
        // retry until the deadline (connections land in the peer's backlog
        // even before it calls accept).
        for peer in 0..rank {
            let stream = loop {
                match TcpStream::connect_timeout(
                    &addrs[peer as usize],
                    Duration::from_millis(250),
                ) {
                    Ok(s) => break s,
                    Err(e) => {
                        if Instant::now() >= deadline {
                            return Err(TransportError::Timeout(format!(
                                "rank {rank}: dialing rank {peer} at {}: {e}",
                                addrs[peer as usize]
                            )));
                        }
                        std::thread::sleep(Duration::from_millis(10));
                    }
                }
            };
            stream.set_nodelay(true)?;
            let mut s = stream;
            write_u64(&mut s, MAGIC)?;
            write_u64(&mut s, rank)?;
            s.flush()?;
            streams[peer as usize] = Some(s);
        }
        // Accept phase: higher ranks, identified by their hello.
        listener.set_nonblocking(true)?;
        let mut accepted = 0u64;
        while accepted < p - 1 - rank {
            match listener.accept() {
                Ok((stream, _)) => {
                    stream.set_nonblocking(false)?;
                    stream.set_nodelay(true)?;
                    stream.set_read_timeout(Some(timeout))?;
                    stream.set_write_timeout(Some(timeout))?;
                    let mut s = stream;
                    let magic = read_u64(&mut s)?;
                    if magic != MAGIC {
                        return Err(TransportError::Protocol(format!(
                            "rank {rank}: bad hello magic {magic:#018x}"
                        )));
                    }
                    let peer = read_u64(&mut s)?;
                    if peer <= rank || peer >= p {
                        return Err(TransportError::Protocol(format!(
                            "rank {rank}: hello from unexpected rank {peer}"
                        )));
                    }
                    if streams[peer as usize].is_some() {
                        return Err(TransportError::Protocol(format!(
                            "rank {rank}: duplicate connection from rank {peer}"
                        )));
                    }
                    streams[peer as usize] = Some(s);
                    accepted += 1;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        return Err(TransportError::Timeout(format!(
                            "rank {rank}: only {accepted} of {} higher ranks connected",
                            p - 1 - rank
                        )));
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(e.into()),
            }
        }
        // Bound both directions: a blocked write (peer not draining) must
        // surface as a timeout, not hang forever.
        for s in streams.iter().flatten() {
            s.set_read_timeout(Some(timeout))?;
            s.set_write_timeout(Some(timeout))?;
        }
        Ok(TcpTransport {
            rank,
            p,
            streams,
            timeout,
        })
    }

    /// Separate-process rendezvous: rank `r` listens on
    /// `host:(base_port + r)`; the listener map is implied by
    /// `(host, base_port)`. All `p` processes call this with the same
    /// parameters and their own `rank`.
    pub fn connect_base_port(
        rank: u64,
        p: u64,
        host: IpAddr,
        base_port: u16,
        timeout: Duration,
    ) -> Result<TcpTransport, TransportError> {
        let mut addrs = Vec::with_capacity(p as usize);
        for r in 0..p {
            let port = u16::try_from(r)
                .ok()
                .and_then(|r16| base_port.checked_add(r16))
                .ok_or_else(|| {
                    TransportError::Protocol(format!(
                        "port range {base_port}..{base_port}+{p} exceeds 65535"
                    ))
                })?;
            addrs.push(SocketAddr::new(host, port));
        }
        let listener = TcpListener::bind(addrs[rank as usize])?;
        TcpTransport::connect(rank, p, listener, &addrs, timeout)
    }

    fn stream(&mut self, peer: u64) -> Result<&mut TcpStream, TransportError> {
        if peer >= self.p || peer == self.rank {
            return Err(TransportError::Collective(format!(
                "rank {}: invalid peer {peer} (p = {})",
                self.rank, self.p
            )));
        }
        self.streams[peer as usize]
            .as_mut()
            .ok_or_else(|| TransportError::Protocol(format!("no link to peer {peer}")))
    }

    fn read_from(&mut self, from: u64) -> Result<WireMsg, TransportError> {
        let rank = self.rank;
        let timeout = self.timeout;
        let stream = self.stream(from)?;
        match read_frame(stream) {
            Ok((tag, data)) => Ok(WireMsg { tag, data }),
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
                Err(TransportError::Timeout(format!(
                    "rank {rank}: waited {timeout:?} for a block from {from}"
                )))
            }
            Err(e) => Err(TransportError::Io(format!(
                "rank {rank}: reading from {from}: {e}"
            ))),
        }
    }
}

impl Transport for TcpTransport {
    fn rank(&self) -> u64 {
        self.rank
    }

    fn size(&self) -> u64 {
        self.p
    }

    fn sendrecv(
        &mut self,
        send: Option<SendSpec>,
        recv_from: Option<u64>,
    ) -> Result<Option<WireMsg>, TransportError> {
        match (send, recv_from) {
            (None, None) => Ok(None),
            (Some(s), None) => {
                let stream = self.stream(s.to)?;
                write_frame(stream, s.tag, &s.data)?;
                Ok(None)
            }
            (None, Some(from)) => self.read_from(from).map(Some),
            (Some(s), Some(from)) => {
                // Send ∥ recv, possibly with the same peer: write on a
                // scoped thread (on a cloned handle) while this thread
                // reads, so cyclic rounds with payloads larger than the
                // socket buffers cannot deadlock.
                let writer = self
                    .stream(s.to)?
                    .try_clone()
                    .map_err(|e| TransportError::Io(format!("clone to {}: {e}", s.to)))?;
                let tag = s.tag;
                let data = s.data;
                std::thread::scope(|scope| {
                    let handle = scope.spawn(move || -> std::io::Result<()> {
                        let mut w = writer;
                        write_frame(&mut w, tag, &data)
                    });
                    let got = self.read_from(from);
                    let wrote = handle
                        .join()
                        .unwrap_or_else(|_| {
                            Err(std::io::Error::new(ErrorKind::Other, "writer panicked"))
                        });
                    wrote.map_err(|e| {
                        TransportError::Io(format!("rank {}: writing: {e}", self.rank))
                    })?;
                    got.map(Some)
                })
            }
        }
    }

    fn barrier(&mut self) -> Result<(), TransportError> {
        // Dissemination barrier over the reserved tag: q = ⌈log₂p⌉ token
        // exchanges; FIFO per pair keeps tokens behind any in-flight data.
        const BARRIER_TAG: u64 = u64::MAX;
        let p = self.p;
        if p == 1 {
            return Ok(());
        }
        let q = crate::sched::ceil_log2(p);
        for k in 0..q {
            let step = 1u64 << k;
            let to = (self.rank + step) % p;
            let from = (self.rank + p - step) % p;
            let got = self.sendrecv(
                Some(SendSpec {
                    to,
                    tag: BARRIER_TAG,
                    data: Vec::new(),
                }),
                Some(from),
            )?;
            match got {
                Some(msg) if msg.tag == BARRIER_TAG && msg.data.is_empty() => {}
                Some(msg) => {
                    return Err(TransportError::Protocol(format!(
                        "rank {}: expected barrier token from {from}, got block {}",
                        self.rank, msg.tag
                    )))
                }
                None => unreachable!("recv_from was Some"),
            }
        }
        Ok(())
    }
}

/// Bind `p` ephemeral-port listeners on localhost and return them with the
/// listener map (collision-free in-process rendezvous).
pub fn bind_mesh(p: u64) -> Result<(Vec<TcpListener>, Vec<SocketAddr>), TransportError> {
    let mut listeners = Vec::with_capacity(p as usize);
    let mut addrs = Vec::with_capacity(p as usize);
    for _ in 0..p {
        let l = TcpListener::bind("127.0.0.1:0")?;
        addrs.push(l.local_addr()?);
        listeners.push(l);
    }
    Ok((listeners, addrs))
}

/// Run `f` as an SPMD program over real localhost sockets, one rank per
/// thread (the wire path is identical to the separate-process mode; only
/// the rendezvous differs). Returns the per-rank results (index = rank).
pub fn run_tcp<R, F>(p: u64, timeout: Duration, f: F) -> Result<Vec<R>, TransportError>
where
    R: Send,
    F: Fn(TcpTransport) -> Result<R, TransportError> + Sync,
{
    assert!(p >= 1, "need at least one rank");
    let (listeners, addrs) = bind_mesh(p)?;
    let mut results: Vec<Option<Result<R, TransportError>>> = (0..p).map(|_| None).collect();
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(p as usize);
        for (rank, listener) in listeners.into_iter().enumerate() {
            let f = &f;
            let addrs = &addrs;
            handles.push(s.spawn(move || {
                let t = TcpTransport::connect(rank as u64, p, listener, addrs, timeout)?;
                f(t)
            }));
        }
        for (rank, h) in handles.into_iter().enumerate() {
            results[rank] = Some(h.join().unwrap_or_else(|_| {
                Err(TransportError::Collective(format!("rank {rank} panicked")))
            }));
        }
    });
    super::drain_results(results, |e| {
        matches!(e, TransportError::Timeout(_) | TransportError::Io(_))
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, 42, b"hello blocks").unwrap();
        write_frame(&mut buf, 7, b"").unwrap();
        let mut r = &buf[..];
        assert_eq!(read_frame(&mut r).unwrap(), (42, b"hello blocks".to_vec()));
        assert_eq!(read_frame(&mut r).unwrap(), (7, Vec::new()));
        assert!(r.is_empty());
    }

    #[test]
    fn frame_cap_rejected() {
        let mut buf = Vec::new();
        write_u64(&mut buf, 1).unwrap();
        write_u64(&mut buf, MAX_FRAME + 1).unwrap();
        let mut r = &buf[..];
        assert_eq!(
            read_frame(&mut r).unwrap_err().kind(),
            ErrorKind::InvalidData
        );
    }

    #[test]
    fn mesh_pairwise_exchange() {
        let results = run_tcp(4, Duration::from_secs(20), |mut t| {
            let partner = t.rank() ^ 1;
            let payload = vec![t.rank() as u8; 9];
            let got = t.sendrecv(
                Some(SendSpec {
                    to: partner,
                    tag: t.rank(),
                    data: payload,
                }),
                Some(partner),
            )?;
            let msg = got.expect("scheduled receive");
            t.barrier()?;
            Ok(msg)
        })
        .unwrap();
        for (r, msg) in results.iter().enumerate() {
            let partner = (r as u64) ^ 1;
            assert_eq!(msg.tag, partner);
            assert_eq!(msg.data, vec![partner as u8; 9]);
        }
    }

    #[test]
    fn large_cyclic_round_does_not_deadlock() {
        // Every rank sends 1 MiB around a ring while receiving 1 MiB —
        // larger than default socket buffers, so this deadlocks unless
        // send ∥ recv is genuinely concurrent.
        let p = 3u64;
        let m = 1 << 20;
        let results = run_tcp(p, Duration::from_secs(30), |mut t| {
            let r = t.rank();
            let got = t.sendrecv(
                Some(SendSpec {
                    to: (r + 1) % p,
                    tag: r,
                    data: vec![r as u8; m],
                }),
                Some((r + p - 1) % p),
            )?;
            Ok(got.expect("scheduled receive"))
        })
        .unwrap();
        for (r, msg) in results.iter().enumerate() {
            let prev = ((r as u64 + p - 1) % p) as u8;
            assert_eq!(msg.tag, prev as u64);
            assert_eq!(msg.data.len(), m);
            assert!(msg.data.iter().all(|&b| b == prev));
        }
    }
}
