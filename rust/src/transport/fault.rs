//! Deterministic fault injection: a wrapper transport that executes a
//! seeded, replayable [`FaultPlan`] against any backend.
//!
//! Production meshes fail in four characteristic ways, and each one is an
//! injectable, deterministic [`FaultAction`]:
//!
//! * **kill-rank-at-round** — the endpoint dies: its `sendrecv_into`
//!   returns a structured [`TransportError::Fault`] at the configured
//!   transport round and every later call fails too, exactly like a
//!   crashed process whose peers then observe timeouts;
//! * **sever-link** — an undirected edge goes down: frames across it are
//!   silently dropped on the send side and the receive side waits out its
//!   deadline before reporting a structured
//!   [`TransportError::Timeout`] with peer/round context (a cut cable,
//!   not a polite hangup);
//! * **delay-round** — one endpoint stalls for a configured duration
//!   before a round (congestion, GC pause, scheduler hiccup);
//! * **corrupt-frame** — a received frame's tag and payload are flipped,
//!   which the collective layer's determinacy check must surface as a
//!   structured [`TransportError::Collective`] instead of delivering
//!   silently wrong bytes.
//!
//! The plan is **shared by every rank** (each [`FaultTransport`] holds an
//! `Arc` of the same plan) and is a pure function of its seed or explicit
//! action list, so a failure scenario is a reproducible test case: same
//! seed, same schedule, same outcome — never a flake. `FaultPlan`
//! round-trips through its [`std::fmt::Display`] form via
//! [`FaultPlan::parse`], which is what the CLI's `--fault-plan` flag and
//! the "seed echoed on failure" replay workflow use.
//!
//! Rounds here are *transport rounds*: the per-endpoint `sendrecv_into`
//! operation counter (the same counter [`crate::transport::FaultCtx`]
//! reports), which on a healthy run is identical across ranks executing
//! the same SPMD collective.

use super::{CostHint, FaultCtx, SendSpec, Transport, TransportError};
use std::sync::Arc;
use std::time::Duration;

/// One injectable fault. See the module docs for the failure taxonomy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    /// Rank `rank` dies at transport round `round`: that round and every
    /// later operation on its endpoint returns [`TransportError::Fault`].
    KillRank {
        /// The rank that dies.
        rank: u64,
        /// The transport round it dies at.
        round: u64,
    },
    /// The undirected link `{a, b}` is down for the whole run: sends
    /// across it vanish, receives across it time out.
    SeverLink {
        /// One end of the severed link.
        a: u64,
        /// The other end.
        b: u64,
    },
    /// Rank `rank` sleeps for `millis` ms before transport round `round`.
    DelayRound {
        /// The delayed rank.
        rank: u64,
        /// The transport round the delay precedes.
        round: u64,
        /// Delay in milliseconds.
        millis: u64,
    },
    /// The frame rank `rank` receives in transport round `round` arrives
    /// corrupted (tag flipped, payload bytes flipped).
    CorruptFrame {
        /// The receiving rank.
        rank: u64,
        /// The transport round whose inbound frame is corrupted.
        round: u64,
    },
}

impl std::fmt::Display for FaultAction {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            FaultAction::KillRank { rank, round } => write!(f, "kill={rank}@{round}"),
            FaultAction::SeverLink { a, b } => write!(f, "sever={a}-{b}"),
            FaultAction::DelayRound {
                rank,
                round,
                millis,
            } => write!(f, "delay={rank}@{round}:{millis}"),
            FaultAction::CorruptFrame { rank, round } => write!(f, "corrupt={rank}@{round}"),
        }
    }
}

/// A seeded, replayable set of [`FaultAction`]s shared by all ranks.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    seed: u64,
    actions: Vec<FaultAction>,
}

/// The xorshift64* step behind [`FaultPlan::from_seed`] — tiny, seeded,
/// and fully deterministic (the offline image has no rand crate, and a
/// reproducible plan must not depend on one anyway).
fn xorshift(state: &mut u64) -> u64 {
    let mut s = *state;
    s ^= s << 13;
    s ^= s >> 7;
    s ^= s << 17;
    *state = s;
    s.wrapping_mul(0x2545_F491_4F6C_DD1D)
}

impl FaultPlan {
    /// An empty plan (no faults) with seed 0.
    pub fn new() -> FaultPlan {
        FaultPlan::default()
    }

    /// Generate a random single-fault scenario for a `p`-rank mesh:
    /// either one rank killed at a round within the first broadcast
    /// phases, or one severed circulant edge. The scenario is a pure
    /// function of `(seed, p)` — replaying with the same seed replays the
    /// identical faults.
    pub fn from_seed(seed: u64, p: u64) -> FaultPlan {
        assert!(p >= 2, "a fault plan needs at least two ranks");
        let mut s = seed | 1; // xorshift must not start at 0
        let skips = crate::sched::Skips::new(p);
        let q = skips.q() as u64;
        let action = if xorshift(&mut s) % 2 == 0 {
            FaultAction::KillRank {
                rank: xorshift(&mut s) % p,
                round: xorshift(&mut s) % (q + 4),
            }
        } else {
            let a = xorshift(&mut s) % p;
            let k = (xorshift(&mut s) % q.max(1)) as usize;
            FaultAction::SeverLink {
                a,
                b: skips.to_proc(a, k),
            }
        };
        FaultPlan {
            seed,
            actions: vec![action],
        }
    }

    /// Parse a comma-separated plan spec — the same syntax
    /// [`std::fmt::Display`] prints, so a failing test's echoed plan can
    /// be replayed verbatim:
    ///
    /// * `kill=R@T` — kill rank `R` at transport round `T`
    /// * `sever=A-B` — sever the undirected link `{A, B}`
    /// * `delay=R@T:MS` — delay rank `R` by `MS` ms before round `T`
    /// * `corrupt=R@T` — corrupt rank `R`'s inbound frame in round `T`
    /// * `seed=N` — add the [`FaultPlan::from_seed`] scenario for seed `N`
    ///
    /// `p` is the mesh size (needed by `seed=`; also used to range-check
    /// explicit ranks).
    pub fn parse(spec: &str, p: u64) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::new();
        let check_rank = |r: u64| -> Result<u64, String> {
            if r >= p {
                Err(format!("rank {r} out of range (p = {p})"))
            } else {
                Ok(r)
            }
        };
        let num = |s: &str| -> Result<u64, String> {
            s.parse::<u64>().map_err(|_| format!("bad number `{s}`"))
        };
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (key, val) = part
                .split_once('=')
                .ok_or_else(|| format!("bad fault spec `{part}` (want key=value)"))?;
            match key {
                "kill" => {
                    let (r, t) = val
                        .split_once('@')
                        .ok_or_else(|| format!("bad kill spec `{val}` (want R@T)"))?;
                    plan.actions.push(FaultAction::KillRank {
                        rank: check_rank(num(r)?)?,
                        round: num(t)?,
                    });
                }
                "sever" => {
                    let (a, b) = val
                        .split_once('-')
                        .ok_or_else(|| format!("bad sever spec `{val}` (want A-B)"))?;
                    let (a, b) = (check_rank(num(a)?)?, check_rank(num(b)?)?);
                    if a == b {
                        return Err(format!("cannot sever the self-link {a}-{b}"));
                    }
                    plan.actions.push(FaultAction::SeverLink { a, b });
                }
                "delay" => {
                    let (r, rest) = val
                        .split_once('@')
                        .ok_or_else(|| format!("bad delay spec `{val}` (want R@T:MS)"))?;
                    let (t, ms) = rest
                        .split_once(':')
                        .ok_or_else(|| format!("bad delay spec `{val}` (want R@T:MS)"))?;
                    plan.actions.push(FaultAction::DelayRound {
                        rank: check_rank(num(r)?)?,
                        round: num(t)?,
                        millis: num(ms)?,
                    });
                }
                "corrupt" => {
                    let (r, t) = val
                        .split_once('@')
                        .ok_or_else(|| format!("bad corrupt spec `{val}` (want R@T)"))?;
                    plan.actions.push(FaultAction::CorruptFrame {
                        rank: check_rank(num(r)?)?,
                        round: num(t)?,
                    });
                }
                "seed" => {
                    let seeded = FaultPlan::from_seed(num(val)?, p);
                    plan.seed = seeded.seed;
                    plan.actions.extend(seeded.actions);
                }
                other => return Err(format!("unknown fault kind `{other}`")),
            }
        }
        Ok(plan)
    }

    /// Add a kill-rank-at-round fault.
    pub fn kill(mut self, rank: u64, round: u64) -> FaultPlan {
        self.actions.push(FaultAction::KillRank { rank, round });
        self
    }

    /// Add a severed undirected link.
    pub fn sever(mut self, a: u64, b: u64) -> FaultPlan {
        assert_ne!(a, b, "cannot sever a self-link");
        self.actions.push(FaultAction::SeverLink { a, b });
        self
    }

    /// Add a pre-round delay.
    pub fn delay(mut self, rank: u64, round: u64, millis: u64) -> FaultPlan {
        self.actions.push(FaultAction::DelayRound {
            rank,
            round,
            millis,
        });
        self
    }

    /// Add an inbound-frame corruption.
    pub fn corrupt(mut self, rank: u64, round: u64) -> FaultPlan {
        self.actions.push(FaultAction::CorruptFrame { rank, round });
        self
    }

    /// The seed this plan was generated from (0 for hand-built plans).
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// The plan's actions.
    pub fn actions(&self) -> &[FaultAction] {
        &self.actions
    }

    /// Every severed undirected edge in the plan — the subgraph mask the
    /// degraded collectives must route around (see
    /// [`crate::sched::LinkMask`]).
    pub fn severed_edges(&self) -> impl Iterator<Item = (u64, u64)> + '_ {
        self.actions.iter().filter_map(|a| match *a {
            FaultAction::SeverLink { a, b } => Some((a, b)),
            _ => None,
        })
    }

    /// Whether the undirected link `{a, b}` is severed.
    pub fn severed(&self, a: u64, b: u64) -> bool {
        self.severed_edges()
            .any(|(x, y)| (x == a && y == b) || (x == b && y == a))
    }

    /// The round at which `rank` dies, if any (the earliest of its kills).
    pub fn kill_round(&self, rank: u64) -> Option<u64> {
        self.actions
            .iter()
            .filter_map(|a| match *a {
                FaultAction::KillRank { rank: r, round } if r == rank => Some(round),
                _ => None,
            })
            .min()
    }

    fn delay_at(&self, rank: u64, round: u64) -> Option<Duration> {
        self.actions.iter().find_map(|a| match *a {
            FaultAction::DelayRound {
                rank: r,
                round: t,
                millis,
            } if r == rank && t == round => Some(Duration::from_millis(millis)),
            _ => None,
        })
    }

    fn corrupt_at(&self, rank: u64, round: u64) -> bool {
        self.actions.iter().any(|a| {
            matches!(*a, FaultAction::CorruptFrame { rank: r, round: t } if r == rank && t == round)
        })
    }

    /// Whether any action is keyed to a transport-round number (kill,
    /// delay, corrupt — everything except `sever`, which is stateless).
    /// Round-keyed plans pin faults to specific op counts, so extra
    /// warm-up traffic would shift every subsequent fault.
    pub fn has_round_keyed(&self) -> bool {
        self.actions.iter().any(|a| {
            !matches!(*a, FaultAction::SeverLink { .. })
        })
    }
}

impl std::fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut sep = "";
        for a in &self.actions {
            write!(f, "{sep}{a}")?;
            sep = ",";
        }
        Ok(())
    }
}

/// A [`Transport`] wrapper executing a shared [`FaultPlan`] against the
/// wrapped backend. Create one per rank over the rank's real transport;
/// all wrappers share one plan `Arc`.
///
/// `recv_deadline` bounds how long a severed-link receive "waits" before
/// reporting its structured timeout — pass the same deadline the inner
/// transport uses so fault-injected timeouts and real ones are
/// indistinguishable to the caller.
pub struct FaultTransport<T> {
    inner: T,
    plan: Arc<FaultPlan>,
    recv_deadline: Duration,
    ops: u64,
    dead: bool,
    measured: Option<CostHint>,
}

impl<T: Transport> FaultTransport<T> {
    /// Wrap `inner` under `plan`.
    pub fn new(inner: T, plan: Arc<FaultPlan>, recv_deadline: Duration) -> FaultTransport<T> {
        FaultTransport {
            inner,
            plan,
            recv_deadline,
            ops: 0,
            dead: false,
            measured: None,
        }
    }

    /// Unwrap back to the underlying transport (e.g. for post-failure
    /// recovery: the killed rank's *inner* transport is still intact).
    pub fn into_inner(self) -> T {
        self.inner
    }

    /// The shared plan.
    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Whether a kill fault already fired on this endpoint.
    pub fn is_dead(&self) -> bool {
        self.dead
    }

    fn check_alive(&mut self, round: u64) -> Result<(), TransportError> {
        let rank = self.inner.rank();
        if self.dead {
            return Err(TransportError::fault_at(
                format!("rank {rank}: endpoint killed by fault plan"),
                FaultCtx::default().with_round(round),
            ));
        }
        if let Some(at) = self.plan.kill_round(rank) {
            if round >= at {
                self.dead = true;
                return Err(TransportError::fault_at(
                    format!("rank {rank}: killed at transport round {at} by fault plan"),
                    FaultCtx::default().with_round(round),
                ));
            }
        }
        Ok(())
    }
}

impl<T: Transport> Transport for FaultTransport<T> {
    fn rank(&self) -> u64 {
        self.inner.rank()
    }

    fn size(&self) -> u64 {
        self.inner.size()
    }

    fn sendrecv_into(
        &mut self,
        send: Option<SendSpec<'_>>,
        recv_from: Option<u64>,
        recv_buf: &mut Vec<u8>,
    ) -> Result<Option<u64>, TransportError> {
        let round = self.ops;
        self.ops += 1;
        let rank = self.inner.rank();
        self.check_alive(round)?;
        if let Some(d) = self.plan.delay_at(rank, round) {
            std::thread::sleep(d);
        }
        // A send across a severed link vanishes: the cable is cut, not
        // the protocol — the peer discovers it by timing out.
        let send = match send {
            Some(s) if self.plan.severed(rank, s.to) => None,
            other => other,
        };
        if let Some(from) = recv_from {
            if self.plan.severed(rank, from) {
                // The frame can never arrive. Perform any surviving send
                // half, wait out the deadline, and report the same
                // structured timeout a dead link produces.
                self.inner.sendrecv_into(send, None, recv_buf)?;
                std::thread::sleep(self.recv_deadline);
                return Err(TransportError::timeout_at(
                    format!(
                        "rank {rank}: waited {:?} for a block from {from} (link severed)",
                        self.recv_deadline
                    ),
                    FaultCtx::peer(from).with_round(round),
                ));
            }
        }
        let got = self.inner.sendrecv_into(send, recv_from, recv_buf)?;
        if got.is_some() && self.plan.corrupt_at(rank, round) {
            // Bit-flip the frame: tag and every payload byte. The
            // collective layer's determinacy check (asserted tags, block
            // sizes) must turn this into a structured error.
            for b in recv_buf.iter_mut() {
                *b = !*b;
            }
            return Ok(got.map(|tag| tag ^ 1));
        }
        Ok(got)
    }

    fn warm_up(&mut self) -> Result<(), TransportError> {
        // Probing through `self` (not `inner`) means the α/β exchange sees
        // the same injected faults the collective will — a severed probe
        // link degrades to the static hint instead of reporting a latency
        // the broken mesh can't deliver. But probe traffic advances the op
        // counter, so under a round-keyed plan (kill/delay/corrupt pinned
        // to specific rounds) we skip it entirely: shifting every fault to
        // a different round would break replayability.
        if self.plan.has_round_keyed() {
            return Ok(());
        }
        match super::measure_link_hint(self) {
            Ok(h) => self.measured = h,
            Err(e) => super::warn_warm_up(self.rank(), "α/β probe", &e),
        }
        Ok(())
    }

    fn warm_peers(&mut self, peers: &[u64]) -> Result<(), TransportError> {
        self.inner.warm_peers(peers)
    }

    fn cost_hint(&self) -> CostHint {
        self.measured.unwrap_or_else(|| self.inner.cost_hint())
    }

    fn barrier(&mut self) -> Result<(), TransportError> {
        let round = self.ops;
        self.check_alive(round)?;
        self.inner.barrier()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::thread::run_threads;
    use crate::transport::Payload;

    #[test]
    fn plan_display_parse_roundtrip() {
        let plan = FaultPlan::new()
            .kill(3, 5)
            .sever(1, 4)
            .delay(2, 3, 50)
            .corrupt(0, 7);
        let spec = plan.to_string();
        assert_eq!(spec, "kill=3@5,sever=1-4,delay=2@3:50,corrupt=0@7");
        let parsed = FaultPlan::parse(&spec, 8).unwrap();
        assert_eq!(parsed.actions(), plan.actions());
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(FaultPlan::parse("kill=9@0", 8).is_err(), "rank out of range");
        assert!(FaultPlan::parse("sever=2-2", 8).is_err(), "self-link");
        assert!(FaultPlan::parse("explode=1", 8).is_err(), "unknown kind");
        assert!(FaultPlan::parse("kill=1", 8).is_err(), "missing round");
    }

    #[test]
    fn seeded_plans_are_deterministic_and_vary() {
        for p in [4u64, 7, 16, 33] {
            let mut distinct = std::collections::HashSet::new();
            for seed in 0..64u64 {
                let a = FaultPlan::from_seed(seed, p);
                let b = FaultPlan::from_seed(seed, p);
                assert_eq!(a, b, "seed {seed} p {p} must replay identically");
                assert_eq!(a.actions().len(), 1);
                if let FaultAction::SeverLink { a: x, b: y } = a.actions()[0] {
                    assert_ne!(x, y, "seed {seed} p {p}: self-link");
                    assert!(x < p && y < p);
                }
                distinct.insert(format!("{a}"));
            }
            assert!(distinct.len() > 8, "p {p}: seeds must cover many scenarios");
        }
    }

    #[test]
    fn kill_fires_at_round_and_stays_dead() {
        let plan = Arc::new(FaultPlan::new().kill(1, 2));
        let outcomes = run_threads(2, Duration::from_millis(200), move |t| {
            let rank = t.rank();
            let mut ft = FaultTransport::new(t, plan.clone(), Duration::from_millis(200));
            let peer = rank ^ 1;
            let mut buf = Vec::new();
            let mut errs = Vec::new();
            for _ in 0..4 {
                let r = ft.sendrecv_into(
                    Some(SendSpec {
                        to: peer,
                        tag: 0,
                        data: Payload::Bytes(&[rank as u8]),
                    }),
                    Some(peer),
                    &mut buf,
                );
                if let Err(e) = r {
                    errs.push(e.to_string());
                }
            }
            Ok(errs)
        })
        .unwrap();
        // Rank 1 dies at its 3rd op and every op after; rank 0 times out
        // from then on.
        assert!(outcomes[1][0].contains("killed at transport round 2"), "{:?}", outcomes[1]);
        assert_eq!(outcomes[1].len(), 2, "dead rank fails every later op");
        assert!(!outcomes[0].is_empty(), "survivor must observe timeouts");
        assert!(outcomes[0][0].contains("peer=1"), "{:?}", outcomes[0]);
    }

    #[test]
    fn severed_link_times_out_with_context() {
        let plan = Arc::new(FaultPlan::new().sever(0, 1));
        let outcomes = run_threads(2, Duration::from_millis(100), move |t| {
            let rank = t.rank();
            let mut ft = FaultTransport::new(t, plan.clone(), Duration::from_millis(100));
            let peer = rank ^ 1;
            let mut buf = Vec::new();
            let err = ft
                .sendrecv_into(
                    Some(SendSpec {
                        to: peer,
                        tag: 0,
                        data: Payload::Bytes(&[7]),
                    }),
                    Some(peer),
                    &mut buf,
                )
                .unwrap_err();
            match &err {
                TransportError::Timeout { ctx, .. } => {
                    assert_eq!(ctx.peer, Some(peer), "{err}");
                    assert_eq!(ctx.round, Some(0), "{err}");
                }
                other => panic!("want structured timeout, got {other}"),
            }
            Ok(())
        });
        outcomes.unwrap();
    }

    #[test]
    fn warm_up_survives_a_severed_probe_link() {
        // The α/β warm-up probe rides the ring, and sever=0-1 cuts it.
        // warm_up must degrade to the static hint and report Ok on every
        // rank — a broken probe is a lost optimisation, not a lost job.
        let plan = Arc::new(FaultPlan::new().sever(0, 1));
        run_threads(2, Duration::from_millis(100), move |t| {
            let static_hint = t.cost_hint();
            let mut ft = FaultTransport::new(t, plan.clone(), Duration::from_millis(50));
            ft.warm_up()?;
            assert_eq!(
                ft.cost_hint(),
                static_hint,
                "failed probe must leave the static hint in place"
            );
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn round_keyed_plans_skip_the_warm_up_probe() {
        // kill=1@2 pins a fault to transport round 2; probe traffic would
        // advance the op counter past it before the collective starts.
        let plan = Arc::new(FaultPlan::new().kill(1, 2));
        assert!(plan.has_round_keyed());
        assert!(!FaultPlan::new().sever(0, 1).has_round_keyed());
        run_threads(2, Duration::from_millis(200), move |t| {
            let mut ft = FaultTransport::new(t, plan.clone(), Duration::from_millis(200));
            ft.warm_up()?;
            assert_eq!(ft.ops, 0, "warm_up must not consume transport rounds");
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn corrupt_frame_flips_tag_and_bytes() {
        let plan = Arc::new(FaultPlan::new().corrupt(1, 0));
        run_threads(2, Duration::from_secs(5), move |t| {
            let rank = t.rank();
            let mut ft = FaultTransport::new(t, plan.clone(), Duration::from_secs(5));
            let peer = rank ^ 1;
            let mut buf = Vec::new();
            let got = ft.sendrecv_into(
                Some(SendSpec {
                    to: peer,
                    tag: 4,
                    data: Payload::Bytes(&[0x0F]),
                }),
                Some(peer),
                &mut buf,
            )?;
            if rank == 1 {
                assert_eq!(got, Some(5), "tag must arrive flipped");
                assert_eq!(buf, vec![0xF0], "payload must arrive flipped");
            } else {
                assert_eq!(got, Some(4));
                assert_eq!(buf, vec![0x0F]);
            }
            Ok(())
        })
        .unwrap();
    }
}
