//! Profiling driver for the §Perf pass (EXPERIMENTS.md):
//!
//! ```sh
//! cargo build --release --bin profme
//! perf record -g ./target/release/profme && perf report
//! ```
//!
//! Hammers the new O(log p) schedule construction at p ≈ 2²⁰ so `perf`
//! attributes cost to `Dfs::run` / `send_schedule_into` /
//! `recv_schedule_into` (the Table 3 hot path).
//!
//! The driver measures itself through the observability recorder rather
//! than ad-hoc timers: each rep of each kernel records one
//! [`RoundEvent`] (lane = kernel, round = rep, bytes = schedule words
//! written) via the always-compiled [`Recorder::record`] path — no `obs`
//! cargo feature needed — and the run ends with the recorder's own
//! per-round latency table, so `profme` reports its phase timings even
//! when `perf` is not attached.

use nblock_bcast::obs::{export, Recorder, RoundEvent, NO_BLOCK, NO_PEER};
use nblock_bcast::sched::{recv_schedule_into_fast, send_schedule_into, Scratch, Skips};

const P: u64 = 1_048_575;
const STEP: usize = 7;
const REPS: u64 = 6;

/// Recorder lanes (the table's per-round "ranks" are reps here).
const LANE_RECV: u64 = 0;
const LANE_SEND: u64 = 1;

fn main() {
    let skips = Skips::new(P);
    let q = skips.q();
    let mut scratch = Scratch::new();
    let (mut recv, mut send, mut tmp) = (vec![0i64; q], vec![0i64; q], vec![0i64; q]);
    let rec = Recorder::new(2, REPS as usize);
    let ranks = (0..P).step_by(STEP).count() as u64;
    // Each kernel writes one q-word schedule per rank.
    let pass_bytes = ranks * q as u64 * 8;
    println!("profme: schedule construction at p = {P} (q = {q}), {ranks} ranks/pass, {REPS} reps");
    for rep in 0..REPS {
        let t0 = rec.now_ns();
        for r in (0..P).step_by(STEP) {
            recv_schedule_into_fast(&skips, r, &mut scratch, &mut recv);
            std::hint::black_box(&recv);
        }
        let t1 = rec.now_ns();
        rec.record(
            LANE_RECV,
            RoundEvent {
                round: rep,
                peer: NO_PEER,
                block: NO_BLOCK,
                bytes: pass_bytes,
                t_start_ns: t0,
                t_end_ns: t1,
            },
        );
        for r in (0..P).step_by(STEP) {
            send_schedule_into(&skips, r, &mut scratch, &mut tmp, &mut send);
            std::hint::black_box(&send);
        }
        let t2 = rec.now_ns();
        rec.record(
            LANE_SEND,
            RoundEvent {
                round: rep,
                peer: NO_PEER,
                block: NO_BLOCK,
                bytes: pass_bytes,
                t_start_ns: t1,
                t_end_ns: t2,
            },
        );
    }
    for (lane, name) in [(LANE_RECV, "recv_schedule_into_fast"), (LANE_SEND, "send_schedule_into")] {
        let evs = rec.events(lane);
        let min = evs.iter().map(RoundEvent::duration_ns).min().unwrap_or(0);
        println!(
            "  {name:<24}: best pass {} ({:.1} ns/rank)",
            nblock_bcast::bench_support::fmt_time(min as f64 * 1e-9),
            min as f64 / ranks as f64,
        );
    }
    println!("per-rep timings (lane 0 = recv kernel, lane 1 = send kernel):");
    print!("{}", export::round_table(&rec.all_events()));
}
