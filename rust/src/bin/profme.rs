//! Profiling driver for the §Perf pass (EXPERIMENTS.md):
//!
//! ```sh
//! cargo build --release --bin profme
//! perf record -g ./target/release/profme && perf report
//! ```
//!
//! Hammers the new O(log p) schedule construction at p ≈ 2²⁰ so `perf`
//! attributes cost to `Dfs::run` / `send_schedule_into` /
//! `recv_schedule_into` (the Table 3 hot path).

use nblock_bcast::sched::{recv_schedule_into_fast, send_schedule_into, Scratch, Skips};

fn main() {
    let skips = Skips::new(1_048_575);
    let q = skips.q();
    let mut scratch = Scratch::new();
    let (mut recv, mut send, mut tmp) = (vec![0i64; q], vec![0i64; q], vec![0i64; q]);
    for rep in 0..6u64 {
        for r in (0..1_048_575u64).step_by(7) {
            recv_schedule_into_fast(&skips, r, &mut scratch, &mut recv);
            send_schedule_into(&skips, r, &mut scratch, &mut tmp, &mut send);
            std::hint::black_box((&recv, &send, rep));
        }
    }
}
