//! Leader coordinator: drives an end-to-end n-block broadcast where the
//! per-round payload operations run through the PJRT executables authored
//! in JAX/Pallas.
//!
//! Topology: one leader (this process) owns the round loop and the
//! schedules (computed per simulated rank with the paper's `O(log p)`
//! algorithms); each simulated rank owns an `(n, B)` f32 block buffer that
//! lives as an XLA literal. Per communication round `t`:
//!
//! 1. *pack*: every sending rank runs the `gather` artifact to extract the
//!    scheduled block from its buffer (pre-round state — Condition 4
//!    guarantees the block was received in an earlier round);
//! 2. *exchange*: the one-ported simulated network moves the rows
//!    (and accounts time under the cost model);
//! 3. *merge*: every receiving rank runs the `bcast_step` artifact to
//!    write the incoming row at its scheduled receive block.
//!
//! After `n-1+⌈log₂p⌉` rounds every rank's buffer is verified two ways:
//! block checksums through the `checksum` artifact, and a byte-exact
//! comparison against the root payload. Python is not involved anywhere —
//! the artifacts were compiled by `make artifacts`.

use crate::runtime::{ArtifactSet, LoadedFn, Runtime};
use crate::sched::{BcastPlan, Schedule, Skips};
use crate::simulator::{CostModel, Engine, Msg};
use anyhow::{anyhow, bail, Context, Result};
use std::time::Instant;

/// Configuration for the end-to-end PJRT broadcast.
#[derive(Debug, Clone)]
pub struct E2eConfig {
    /// Simulated ranks.
    pub p: u64,
    /// Broadcast root.
    pub root: u64,
    /// Cost model for the simulated interconnect.
    pub cost: CostModel,
}

/// Metrics of one end-to-end run.
#[derive(Debug, Clone)]
pub struct E2eReport {
    pub p: u64,
    pub n: usize,
    pub block_elems: usize,
    pub rounds: usize,
    /// Wall-clock seconds for the whole round loop (PJRT included).
    pub wall_s: f64,
    /// Simulated network seconds under the cost model.
    pub sim_s: f64,
    /// Broadcast payload bytes (n * B * 4).
    pub payload_bytes: u64,
    /// Wall-clock payload throughput per receiving rank, bytes/s.
    pub goodput_bps: f64,
    /// Mean wall-clock per round, seconds.
    pub round_latency_s: f64,
    /// PJRT executions performed.
    pub pjrt_calls: u64,
}

/// The leader: compiled artifacts + round loop.
pub struct Coordinator {
    rt: Runtime,
    set: ArtifactSet,
    step: LoadedFn,
    gather: LoadedFn,
    checksum: LoadedFn,
}

impl Coordinator {
    /// Load and compile the artifact set (once; reused across runs).
    pub fn new(artifact_dir: &std::path::Path) -> Result<Coordinator> {
        let set = ArtifactSet::discover(artifact_dir)?;
        let rt = Runtime::cpu()?;
        let step = rt.load_hlo_text(&set.path("bcast_step")?)?;
        let gather = rt.load_hlo_text(&set.path("gather")?)?;
        let checksum = rt.load_hlo_text(&set.path("checksum")?)?;
        Ok(Coordinator {
            rt,
            set,
            step,
            gather,
            checksum,
        })
    }

    pub fn platform(&self) -> String {
        self.rt.platform()
    }

    pub fn artifact_shape(&self) -> (usize, usize) {
        (self.set.n, self.set.b)
    }

    fn zeros_buffer(&self) -> Result<xla::Literal> {
        xla::Literal::vec1(&vec![0f32; self.set.n * self.set.b])
            .reshape(&[self.set.n as i64, self.set.b as i64])
            .map_err(|e| anyhow!("reshape: {e:?}"))
    }

    /// Root payload: block i holds value pattern i + lane/B (matches
    /// `python/compile/model.py::init_buffer`).
    fn root_buffer(&self) -> Result<xla::Literal> {
        let (n, b) = (self.set.n, self.set.b);
        let mut v = Vec::with_capacity(n * b);
        for i in 0..n {
            for l in 0..b {
                v.push(i as f32 + (l as f32) / (b as f32));
            }
        }
        xla::Literal::vec1(&v)
            .reshape(&[n as i64, b as i64])
            .map_err(|e| anyhow!("reshape: {e:?}"))
    }

    /// Extract one block row from a rank buffer via the gather artifact.
    fn pack_block(&self, buf: &xla::Literal, blk: usize) -> Result<Vec<f32>> {
        // The gather artifact takes a (q,)-index vector; pad with -1
        // (negative = no block, produces zero rows we ignore).
        let mut idx = vec![-1i32; self.set.q];
        idx[0] = blk as i32;
        let out = self
            .gather
            .run(&[buf.clone(), xla::Literal::vec1(&idx)])?;
        let rows = out[0].to_vec::<f32>()?;
        Ok(rows[..self.set.b].to_vec())
    }

    /// Merge an incoming row into a rank buffer via the bcast_step artifact.
    fn merge_block(&self, buf: &xla::Literal, row: &[f32], blk: usize) -> Result<xla::Literal> {
        let out = self.step.run(&[
            buf.clone(),
            xla::Literal::vec1(row),
            xla::Literal::scalar(blk as i32),
            xla::Literal::scalar(-1i32), // no gather needed here
        ])?;
        Ok(out.into_iter().next().unwrap())
    }

    /// Run the full broadcast; returns metrics after verifying delivery.
    pub fn run_bcast(&self, cfg: &E2eConfig) -> Result<E2eReport> {
        let p = cfg.p;
        let (n, b) = (self.set.n, self.set.b);
        if p < 2 {
            bail!("need p >= 2");
        }
        let skips = Skips::new(p);
        let plans: Vec<BcastPlan> = (0..p)
            .map(|r| {
                let rel = (r + p - cfg.root) % p;
                BcastPlan::new(Schedule::compute(&skips, rel), n)
            })
            .collect();
        let mut eng = Engine::new(p, cfg.cost);
        let mut bufs: Vec<xla::Literal> = (0..p)
            .map(|r| {
                if r == cfg.root {
                    self.root_buffer()
                } else {
                    self.zeros_buffer()
                }
            })
            .collect::<Result<_>>()?;

        let rounds = plans[0].num_rounds();
        let mut pjrt_calls = 0u64;
        let started = Instant::now();
        for t in 0..rounds {
            // Pack phase (pre-round state).
            let mut msgs: Vec<Msg> = Vec::with_capacity(p as usize);
            for r in 0..p {
                let a = plans[r as usize].action(t);
                let rel = (r + p - cfg.root) % p;
                let to_rel = skips.to_proc(rel, a.k);
                if to_rel == 0 {
                    continue;
                }
                if let Some(sb) = a.send_block {
                    let row = self.pack_block(&bufs[r as usize], sb)?;
                    pjrt_calls += 1;
                    let bytes = (row.len() * 4) as u64;
                    msgs.push(Msg {
                        from: r,
                        to: (to_rel + cfg.root) % p,
                        bytes,
                        tag: sb as u64,
                        data: Some(row.iter().flat_map(|v| v.to_le_bytes()).collect()),
                    });
                }
            }
            // Exchange (one-ported checks + cost accounting).
            let inbox = eng
                .exchange(msgs)
                .map_err(|e| anyhow!("round {t}: {e}"))?;
            // Merge phase.
            for r in 0..p {
                if r == cfg.root {
                    continue;
                }
                let expected = plans[r as usize].action(t).recv_block;
                match (inbox[r as usize].as_ref(), expected) {
                    (None, None) => {}
                    (Some(msg), Some(blk)) => {
                        if msg.tag != blk as u64 {
                            bail!("rank {r} round {t}: got block {} want {blk}", msg.tag);
                        }
                        let bytes = msg.data.as_ref().unwrap();
                        let row: Vec<f32> = bytes
                            .chunks_exact(4)
                            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                            .collect();
                        bufs[r as usize] = self.merge_block(&bufs[r as usize], &row, blk)?;
                        pjrt_calls += 1;
                    }
                    (got, want) => bail!(
                        "rank {r} round {t}: inbox {:?} vs scheduled {:?}",
                        got.map(|m| m.tag),
                        want
                    ),
                }
            }
        }
        let wall_s = started.elapsed().as_secs_f64();

        // Verification 1: block checksums through the checksum artifact.
        let root_sums = self.checksum.run(&[bufs[cfg.root as usize].clone()])?[0].to_vec::<f32>()?;
        for r in 0..p {
            let sums = self.checksum.run(&[bufs[r as usize].clone()])?[0].to_vec::<f32>()?;
            if sums != root_sums {
                bail!("rank {r}: checksum mismatch {sums:?} vs {root_sums:?}");
            }
        }
        // Verification 2: byte-exact buffers.
        let root_vec = bufs[cfg.root as usize].to_vec::<f32>().context("root buf")?;
        for r in 0..p {
            let v = bufs[r as usize].to_vec::<f32>()?;
            if v != root_vec {
                bail!("rank {r}: payload mismatch");
            }
        }

        let payload_bytes = (n * b * 4) as u64;
        Ok(E2eReport {
            p,
            n,
            block_elems: b,
            rounds,
            wall_s,
            sim_s: eng.time_s,
            payload_bytes,
            goodput_bps: payload_bytes as f64 * (p - 1) as f64 / wall_s,
            round_latency_s: wall_s / rounds as f64,
            pjrt_calls,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::default_artifact_dir;

    #[test]
    fn e2e_broadcast_small() {
        let dir = default_artifact_dir();
        let Ok(coord) = Coordinator::new(&dir) else {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        };
        for (p, root) in [(4u64, 0u64), (6, 2), (9, 8)] {
            let report = coord
                .run_bcast(&E2eConfig {
                    p,
                    root,
                    cost: CostModel::flat_default(),
                })
                .unwrap_or_else(|e| panic!("p={p} root={root}: {e}"));
            let q = crate::sched::ceil_log2(p);
            assert_eq!(report.rounds, report.n - 1 + q);
            assert!(report.pjrt_calls > 0);
        }
    }
}
