//! `nblock` — CLI entry point. See `nblock help`.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = nblock_bcast::cli::run(argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
