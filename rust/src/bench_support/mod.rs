//! Minimal measurement + deterministic-randomness harness.
//!
//! The offline image has no `criterion`/`proptest`; this module provides the
//! two pieces the benches and property tests need: a warmup+repetition
//! timer with robust statistics (median/min), and a small xorshift RNG for
//! reproducible randomized tests. The Table 3 harness intentionally mirrors
//! the paper's methodology (total `clock()` time over all ranks per `p`,
//! divided by `p` and averaged over the range).

use std::time::Instant;

/// Timing statistics over repetitions, in seconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Timing {
    pub reps: usize,
    pub min_s: f64,
    pub median_s: f64,
    pub mean_s: f64,
    pub max_s: f64,
}

impl Timing {
    pub fn from_samples(mut samples: Vec<f64>) -> Timing {
        assert!(!samples.is_empty());
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let reps = samples.len();
        Timing {
            reps,
            min_s: samples[0],
            median_s: samples[reps / 2],
            mean_s: samples.iter().sum::<f64>() / reps as f64,
            max_s: samples[reps - 1],
        }
    }
}

/// Time `f` with `warmup` unmeasured and `reps` measured repetitions.
/// `f` must return something observable to keep the optimizer honest.
pub fn time_reps<T, F: FnMut() -> T>(warmup: usize, reps: usize, mut f: F) -> Timing {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let samples: Vec<f64> = (0..reps)
        .map(|_| {
            let t0 = Instant::now();
            std::hint::black_box(f());
            t0.elapsed().as_secs_f64()
        })
        .collect();
    Timing::from_samples(samples)
}

/// Time one invocation of `f` (for inherently long-running workloads).
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// xorshift64* — deterministic RNG for property tests (no `rand` offline).
#[derive(Debug, Clone)]
pub struct XorShift {
    state: u64,
}

impl XorShift {
    pub fn new(seed: u64) -> XorShift {
        XorShift {
            state: seed.max(1).wrapping_mul(0x9E3779B97F4A7C15),
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[0, bound)`; `bound > 0`.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        self.next_u64() % bound
    }

    /// Uniform in `[lo, hi]`.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo + 1)
    }
}

/// Human-readable byte count (for table output).
pub fn fmt_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.1} {}", UNITS[u])
    }
}

/// Seconds → human-readable (µs/ms/s).
pub fn fmt_time(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.3} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{s:.3} s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_stats() {
        let t = Timing::from_samples(vec![3.0, 1.0, 2.0]);
        assert_eq!(t.min_s, 1.0);
        assert_eq!(t.median_s, 2.0);
        assert_eq!(t.max_s, 3.0);
        assert!((t.mean_s - 2.0).abs() < 1e-12);
    }

    #[test]
    fn xorshift_deterministic_and_spread() {
        let mut a = XorShift::new(7);
        let mut b = XorShift::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = XorShift::new(8);
        let mut hits = [0usize; 10];
        for _ in 0..10_000 {
            hits[c.below(10) as usize] += 1;
        }
        assert!(hits.iter().all(|&h| h > 500), "{hits:?}");
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_bytes(12), "12 B");
        assert_eq!(fmt_bytes(2048), "2.0 KiB");
        assert!(fmt_time(1e-6).contains("µs"));
        assert!(fmt_time(0.5).contains("ms"));
    }
}
