//! The metrics registry: relaxed atomic counters behind one
//! [`snapshot`] surface.
//!
//! Two kinds of counters live here:
//!
//! * **wire/pool counters** — process-global, bumped by the transports
//!   and [`crate::transport::BufferPool`] through the `on_*` hooks below.
//!   The hooks compile to nothing without the `obs` cargo feature (the
//!   overhead contract of [`crate::obs`]), so a default build reports
//!   zeros;
//! * **schedule-cache counters** ([`CacheCounters`]) — per-instance,
//!   owned by each [`crate::sched::cache::ScheduleCache`] and always
//!   maintained (they predate this module and sit off the per-round hot
//!   path). [`snapshot`] folds in the global cache's counts.
//!
//! All loads and stores are `Ordering::Relaxed`: these are statistics,
//! not synchronization, and every reader (CLI, bench JSON emitters,
//! tests) tolerates the slight skew of concurrent increments.

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};

/// A relaxed atomic event counter.
pub struct Counter(AtomicU64);

impl Counter {
    /// A zeroed counter (const, so counters can live in statics).
    pub const fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }

    /// Add `n` (relaxed).
    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Add 1 (relaxed).
    #[inline]
    pub fn incr(&self) {
        self.add(1);
    }

    /// Current value (relaxed).
    #[inline]
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    /// Reset to zero (relaxed).
    #[inline]
    pub fn reset(&self) {
        self.0.store(0, Ordering::Relaxed);
    }
}

impl Default for Counter {
    fn default() -> Counter {
        Counter::new()
    }
}

impl fmt::Debug for Counter {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Counter({})", self.get())
    }
}

/// The hit/miss/eviction counters of one schedule cache — the one stat
/// block that is always live (see the module docs). `reset` is what lets
/// `bench_schedule.rs` isolate its warm series from cold-phase counts.
#[derive(Debug, Default)]
pub struct CacheCounters {
    /// Served from the shared map or the thread-local front.
    pub hits: Counter,
    /// Computed fresh (including the loser of a build race).
    pub misses: Counter,
    /// Whole `(p, cache-id)` groups dropped by FIFO capacity eviction.
    pub evictions: Counter,
}

impl CacheCounters {
    /// Zeroed counters (const, usable in statics).
    pub const fn new() -> CacheCounters {
        CacheCounters {
            hits: Counter::new(),
            misses: Counter::new(),
            evictions: Counter::new(),
        }
    }

    /// Reset all three to zero.
    pub fn reset(&self) {
        self.hits.reset();
        self.misses.reset();
        self.evictions.reset();
    }
}

/// The process-global wire/pool registry.
struct WireMetrics {
    bytes_sent: Counter,
    bytes_received: Counter,
    frames_sent: Counter,
    frames_received: Counter,
    short_write_continuations: Counter,
    redials: Counter,
    reaped_links: Counter,
    pool_hits: Counter,
    pool_misses: Counter,
}

static WIRE: WireMetrics = WireMetrics {
    bytes_sent: Counter::new(),
    bytes_received: Counter::new(),
    frames_sent: Counter::new(),
    frames_received: Counter::new(),
    short_write_continuations: Counter::new(),
    redials: Counter::new(),
    reaped_links: Counter::new(),
    pool_hits: Counter::new(),
    pool_misses: Counter::new(),
};

/// One payload frame of `bytes` left this rank. No-op without the `obs`
/// feature.
#[inline(always)]
pub fn on_send(_bytes: u64) {
    #[cfg(feature = "obs")]
    {
        WIRE.bytes_sent.add(_bytes);
        WIRE.frames_sent.incr();
    }
}

/// One payload frame of `bytes` arrived at this rank. No-op without the
/// `obs` feature.
#[inline(always)]
pub fn on_recv(_bytes: u64) {
    #[cfg(feature = "obs")]
    {
        WIRE.bytes_received.add(_bytes);
        WIRE.frames_received.incr();
    }
}

/// A vectored frame write returned short and had to continue with the
/// unwritten tail. No-op without the `obs` feature.
#[inline(always)]
pub fn on_short_write_continuation() {
    #[cfg(feature = "obs")]
    WIRE.short_write_continuations.incr();
}

/// A TCP link to a previously-connected peer was re-established (a
/// redial after a reap or drop). No-op without the `obs` feature.
#[inline(always)]
pub fn on_redial() {
    #[cfg(feature = "obs")]
    WIRE.redials.incr();
}

/// `n` idle TCP links were reaped. No-op without the `obs` feature.
#[inline(always)]
pub fn on_reaped(_n: u64) {
    #[cfg(feature = "obs")]
    WIRE.reaped_links.add(_n);
}

/// A buffer-pool `get` was served from the shelf. No-op without the
/// `obs` feature.
#[inline(always)]
pub fn on_pool_hit() {
    #[cfg(feature = "obs")]
    WIRE.pool_hits.incr();
}

/// A buffer-pool `get` had to hand out a fresh (empty) buffer. No-op
/// without the `obs` feature.
#[inline(always)]
pub fn on_pool_miss() {
    #[cfg(feature = "obs")]
    WIRE.pool_misses.incr();
}

/// A point-in-time copy of every counter the registry knows about,
/// including the global schedule cache's.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MetricsSnapshot {
    /// Payload bytes sent by this process's ranks.
    pub bytes_sent: u64,
    /// Payload bytes received.
    pub bytes_received: u64,
    /// Frames sent.
    pub frames_sent: u64,
    /// Frames received.
    pub frames_received: u64,
    /// Vectored-write short-write continuations (TCP).
    pub short_write_continuations: u64,
    /// Re-established TCP links.
    pub redials: u64,
    /// Reaped idle TCP links.
    pub reaped_links: u64,
    /// Buffer-pool gets served warm.
    pub pool_hits: u64,
    /// Buffer-pool gets that handed out a fresh buffer.
    pub pool_misses: u64,
    /// Global schedule-cache hits.
    pub sched_cache_hits: u64,
    /// Global schedule-cache misses.
    pub sched_cache_misses: u64,
    /// Global schedule-cache group evictions.
    pub sched_cache_evictions: u64,
}

impl MetricsSnapshot {
    /// Buffer-pool hit rate in `[0, 1]`, or `None` before any `get`.
    pub fn pool_hit_rate(&self) -> Option<f64> {
        let total = self.pool_hits + self.pool_misses;
        if total == 0 {
            None
        } else {
            Some(self.pool_hits as f64 / total as f64)
        }
    }

    /// The snapshot as one JSON object (the `"metrics"` block of the
    /// bench JSONs).
    pub fn to_json(&self) -> String {
        format!(
            concat!(
                "{{\"bytes_sent\":{},\"bytes_received\":{},",
                "\"frames_sent\":{},\"frames_received\":{},",
                "\"short_write_continuations\":{},\"redials\":{},",
                "\"reaped_links\":{},\"pool_hits\":{},\"pool_misses\":{},",
                "\"sched_cache_hits\":{},\"sched_cache_misses\":{},",
                "\"sched_cache_evictions\":{}}}"
            ),
            self.bytes_sent,
            self.bytes_received,
            self.frames_sent,
            self.frames_received,
            self.short_write_continuations,
            self.redials,
            self.reaped_links,
            self.pool_hits,
            self.pool_misses,
            self.sched_cache_hits,
            self.sched_cache_misses,
            self.sched_cache_evictions,
        )
    }
}

impl fmt::Display for MetricsSnapshot {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "metrics:")?;
        writeln!(
            f,
            "  wire     : {} sent / {} received ({} / {} frames)",
            crate::bench_support::fmt_bytes(self.bytes_sent),
            crate::bench_support::fmt_bytes(self.bytes_received),
            self.frames_sent,
            self.frames_received,
        )?;
        writeln!(
            f,
            "  tcp      : {} short-write continuations, {} redials, {} reaped links",
            self.short_write_continuations, self.redials, self.reaped_links,
        )?;
        match self.pool_hit_rate() {
            Some(rate) => writeln!(
                f,
                "  pool     : {} hits / {} misses ({:.1}% warm)",
                self.pool_hits,
                self.pool_misses,
                rate * 100.0,
            )?,
            None => writeln!(f, "  pool     : unused")?,
        }
        write!(
            f,
            "  schedule : {} hits / {} misses / {} evictions",
            self.sched_cache_hits, self.sched_cache_misses, self.sched_cache_evictions,
        )
    }
}

/// Read every counter: the global wire/pool registry plus the global
/// schedule cache's [`CacheCounters`].
pub fn snapshot() -> MetricsSnapshot {
    let cache = crate::sched::cache::global().stats();
    MetricsSnapshot {
        bytes_sent: WIRE.bytes_sent.get(),
        bytes_received: WIRE.bytes_received.get(),
        frames_sent: WIRE.frames_sent.get(),
        frames_received: WIRE.frames_received.get(),
        short_write_continuations: WIRE.short_write_continuations.get(),
        redials: WIRE.redials.get(),
        reaped_links: WIRE.reaped_links.get(),
        pool_hits: WIRE.pool_hits.get(),
        pool_misses: WIRE.pool_misses.get(),
        sched_cache_hits: cache.hits,
        sched_cache_misses: cache.misses,
        sched_cache_evictions: cache.evictions,
    }
}

/// Zero the global wire/pool counters. (Schedule-cache counters are
/// per-instance: reset those through
/// [`crate::sched::cache::ScheduleCache::reset_stats`].)
pub fn reset() {
    WIRE.bytes_sent.reset();
    WIRE.bytes_received.reset();
    WIRE.frames_sent.reset();
    WIRE.frames_received.reset();
    WIRE.short_write_continuations.reset();
    WIRE.redials.reset();
    WIRE.reaped_links.reset();
    WIRE.pool_hits.reset();
    WIRE.pool_misses.reset();
}
