//! Trace export: Chrome-trace/Perfetto JSON, a built-in parser for
//! `trace-report`, and the per-round latency table.
//!
//! The emitted file is the Chrome Trace Event Format: one complete
//! (`"ph":"X"`) event per recorded round, `ts`/`dur` in microseconds,
//! `tid` = rank, with the round/peer/block/bytes tuple under `args` —
//! load it in `chrome://tracing` or <https://ui.perfetto.dev>. The build
//! image vendors no JSON crate, so [`parse_chrome_trace`] is a small
//! hand-rolled reader of exactly this shape (any serde-produced
//! formatting of the same fields also parses: key lookup is textual, not
//! positional).

use super::recorder::{Recorder, RoundEvent, NO_PEER};
use std::collections::BTreeMap;
use std::io::Write as _;

/// Render one event as a Chrome-trace object (no trailing separator).
fn event_json(rank: u64, ev: &RoundEvent) -> String {
    let peer: i64 = if ev.peer == NO_PEER { -1 } else { ev.peer as i64 };
    format!(
        concat!(
            "{{\"name\":\"round {}\",\"cat\":\"round\",\"ph\":\"X\",",
            "\"ts\":{:.3},\"dur\":{:.3},\"pid\":0,\"tid\":{},",
            "\"args\":{{\"round\":{},\"peer\":{},\"block\":{},\"bytes\":{}}}}}"
        ),
        ev.round,
        ev.t_start_ns as f64 / 1000.0,
        ev.duration_ns() as f64 / 1000.0,
        rank,
        ev.round,
        peer,
        ev.block,
        ev.bytes,
    )
}

/// The recorder's retained events as a Chrome-trace JSON document.
pub fn chrome_trace(rec: &Recorder) -> String {
    chrome_trace_from(&rec.all_events())
}

/// `(rank, event)` pairs as a Chrome-trace JSON document.
pub fn chrome_trace_from(events: &[(u64, RoundEvent)]) -> String {
    let mut out = String::from("{\"traceEvents\":[");
    for (i, (rank, ev)) in events.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push('\n');
        out.push_str(&event_json(*rank, ev));
    }
    out.push_str("\n],\"displayTimeUnit\":\"ms\"}\n");
    out
}

/// Write [`chrome_trace`] to `path`.
pub fn write_chrome_trace(path: &str, rec: &Recorder) -> std::io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    f.write_all(chrome_trace(rec).as_bytes())
}

/// First numeric value following `"key":` in `obj`, if any.
fn num_field(obj: &str, key: &str) -> Option<f64> {
    let pat = format!("\"{key}\":");
    let at = obj.find(&pat)? + pat.len();
    let rest = obj[at..].trim_start();
    let end = rest
        .find(|c: char| {
            !(c.is_ascii_digit() || matches!(c, '-' | '+' | '.' | 'e' | 'E'))
        })
        .unwrap_or(rest.len());
    rest[..end].parse().ok()
}

/// Parse a Chrome-trace JSON document back into `(rank, event)` pairs —
/// the inverse of [`chrome_trace`], used by the `trace-report` CLI tool
/// and the round-trip tests.
///
/// This reads the trace-event fields this crate emits (`ts`, `dur`,
/// `tid`, and the `args` tuple) from each `"name"`-delimited object;
/// events missing required fields are an error.
pub fn parse_chrome_trace(text: &str) -> Result<Vec<(u64, RoundEvent)>, String> {
    let body = text
        .split_once("\"traceEvents\"")
        .ok_or_else(|| "not a Chrome-trace document (no \"traceEvents\" key)".to_string())?
        .1;
    let mut out = Vec::new();
    // Each event object starts with its "name" key; the slice up to the
    // next event (or end) contains all of this event's fields.
    for (i, chunk) in body.split("{\"name\"").skip(1).enumerate() {
        let ts = num_field(chunk, "ts");
        let dur = num_field(chunk, "dur");
        let tid = num_field(chunk, "tid");
        let (Some(ts), Some(dur), Some(tid)) = (ts, dur, tid) else {
            return Err(format!("event {i}: missing ts/dur/tid"));
        };
        let round = num_field(chunk, "round").ok_or_else(|| format!("event {i}: missing args.round"))?;
        let peer = num_field(chunk, "peer").ok_or_else(|| format!("event {i}: missing args.peer"))?;
        let block = num_field(chunk, "block").ok_or_else(|| format!("event {i}: missing args.block"))?;
        let bytes = num_field(chunk, "bytes").ok_or_else(|| format!("event {i}: missing args.bytes"))?;
        let t_start_ns = (ts * 1000.0).round() as u64;
        out.push((
            tid as u64,
            RoundEvent {
                round: round as u64,
                peer: if peer < 0.0 { NO_PEER } else { peer as u64 },
                block: block as i64,
                bytes: bytes as u64,
                t_start_ns,
                t_end_ns: t_start_ns + (dur * 1000.0).round() as u64,
            },
        ));
    }
    Ok(out)
}

/// Per-rank retained event counts from `(rank, event)` pairs, rank-sorted.
pub fn per_rank_counts(events: &[(u64, RoundEvent)]) -> Vec<(u64, usize)> {
    let mut counts: BTreeMap<u64, usize> = BTreeMap::new();
    for (rank, _) in events {
        *counts.entry(*rank).or_default() += 1;
    }
    counts.into_iter().collect()
}

/// The per-round latency table: for every semantic round, how many ranks
/// were active, how many bytes their own edges carried, and the
/// min/mean/max round duration across ranks. This is the CLI's `--trace`
/// summary and the `trace-report` body.
pub fn round_table(events: &[(u64, RoundEvent)]) -> String {
    let mut rounds: BTreeMap<u64, Vec<&RoundEvent>> = BTreeMap::new();
    for (_, ev) in events {
        rounds.entry(ev.round).or_default().push(ev);
    }
    let mut out = String::new();
    out.push_str("round  ranks      bytes        min        mean         max\n");
    for (round, evs) in &rounds {
        let bytes: u64 = evs.iter().map(|e| e.bytes).sum();
        let durs: Vec<f64> = evs.iter().map(|e| e.duration_ns() as f64 * 1e-9).collect();
        let min = durs.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = durs.iter().cloned().fold(0.0f64, f64::max);
        let mean = durs.iter().sum::<f64>() / durs.len() as f64;
        out.push_str(&format!(
            "{:>5}  {:>5}  {:>9}  {:>9}  {:>10}  {:>10}\n",
            round,
            evs.len(),
            crate::bench_support::fmt_bytes(bytes),
            crate::bench_support::fmt_time(min),
            crate::bench_support::fmt_time(mean),
            crate::bench_support::fmt_time(max),
        ));
    }
    out
}
