//! Observability: per-rank round tracing, unified transport/cache
//! metrics, Chrome-trace export, and measured α/β calibration.
//!
//! The paper's experiments rely on per-round accounting to compare
//! schedule families; this module is the equivalent measurement substrate
//! for the Transport/collectives stack. It has three parts:
//!
//! * a **round-event recorder** ([`Recorder`]) — fixed-capacity per-rank
//!   ring buffers stamped at [`crate::transport::Transport::sendrecv_into`]
//!   boundaries with `{round, peer, block, bytes, t_start, t_end}`,
//!   exportable as Chrome-trace JSON ([`export::chrome_trace`]) and as a
//!   per-round latency table on the CLI (`--trace`, `trace-report`);
//! * a **metrics registry** ([`metrics`]) — relaxed atomic counters for
//!   wire traffic, TCP link churn, buffer-pool and schedule-cache
//!   behavior, read through one [`metrics::snapshot`] surface;
//! * an **α/β estimator** ([`calibrate`]) — a least-squares fit of the
//!   linear cost model `α + β·bytes` from recorded `(bytes, duration)`
//!   samples, feeding
//!   [`crate::transport::Transport::with_measured_hint`] so
//!   `Algorithm::Auto` and the n* segmentation resolve against measured
//!   constants instead of static ones.
//!
//! ## Overhead contract
//!
//! The recorder hot path is **compiled out** unless the crate is built
//! with the `obs` cargo feature: the hook functions in this module
//! ([`attach`], [`record_round`], [`set_round`], [`now_ns`], ...) are
//! empty inline stubs without it, so the steady-state round loop of the
//! collectives is byte-for-byte the pre-observability code and the
//! counting-allocator bench gates are unaffected. With the feature
//! enabled but no recorder attached (or a [`Recorder::disabled`]
//! recorder), every hook is a thread-local `Option` check that returns
//! immediately — in particular [`now_ns`] returns 0 without touching the
//! clock. With a recorder attached, one event costs two monotonic clock
//! reads and one fixed-slot ring write: no heap allocation, no locks, no
//! shared-cache-line traffic between ranks (each rank owns its ring).
//!
//! The wire/pool counters in [`metrics`] follow the same contract (their
//! increment hooks compile to nothing without the feature). The
//! schedule-cache counters are the exception: they predate this module,
//! sit off the per-round hot path, and are always maintained — they are
//! merely *read* through [`metrics::snapshot`].

#![warn(missing_docs)]

pub mod calibrate;
pub mod export;
pub mod metrics;
mod recorder;

pub use recorder::{Recorder, RoundEvent, NO_BLOCK, NO_PEER};

/// Attach `rec` to the calling thread as rank `rank`: until [`detach`]
/// (or a later `attach`), every instrumented `sendrecv_into` on this
/// thread records one [`RoundEvent`] into `rec`'s ring for `rank`.
///
/// Attaching a [`Recorder::disabled`] recorder detaches. Compiled to a
/// no-op without the `obs` feature.
#[cfg(feature = "obs")]
#[inline]
pub fn attach(rec: &Recorder, rank: u64) {
    recorder::tls::attach(rec, rank);
}

/// Attach `rec` to the calling thread as rank `rank`: until [`detach`]
/// (or a later `attach`), every instrumented `sendrecv_into` on this
/// thread records one [`RoundEvent`] into `rec`'s ring for `rank`.
///
/// Attaching a [`Recorder::disabled`] recorder detaches. Compiled to a
/// no-op without the `obs` feature.
#[cfg(not(feature = "obs"))]
#[inline(always)]
pub fn attach(_rec: &Recorder, _rank: u64) {}

/// Detach any recorder from the calling thread and clear the round
/// context. Compiled to a no-op without the `obs` feature.
#[cfg(feature = "obs")]
#[inline]
pub fn detach() {
    recorder::tls::detach();
}

/// Detach any recorder from the calling thread and clear the round
/// context. Compiled to a no-op without the `obs` feature.
#[cfg(not(feature = "obs"))]
#[inline(always)]
pub fn detach() {}

/// Whether a recorder is attached to the calling thread. Always `false`
/// without the `obs` feature.
#[cfg(feature = "obs")]
#[inline]
pub fn is_active() -> bool {
    recorder::tls::is_active()
}

/// Whether a recorder is attached to the calling thread. Always `false`
/// without the `obs` feature.
#[cfg(not(feature = "obs"))]
#[inline(always)]
pub fn is_active() -> bool {
    false
}

/// Nanoseconds since the attached recorder's epoch, or 0 when no
/// recorder is attached (no clock read) or without the `obs` feature.
/// Transports stamp `t_start` with this before the exchange.
#[cfg(feature = "obs")]
#[inline]
pub fn now_ns() -> u64 {
    recorder::tls::now_ns()
}

/// Nanoseconds since the attached recorder's epoch, or 0 when no
/// recorder is attached (no clock read) or without the `obs` feature.
/// Transports stamp `t_start` with this before the exchange.
#[cfg(not(feature = "obs"))]
#[inline(always)]
pub fn now_ns() -> u64 {
    0
}

/// Set the calling thread's round context: events recorded until
/// [`clear_round`] carry this semantic round number (the collectives'
/// loop index) instead of the ring sequence number. Compiled to a no-op
/// without the `obs` feature.
#[cfg(feature = "obs")]
#[inline]
pub fn set_round(round: u64) {
    recorder::tls::set_round(round);
}

/// Set the calling thread's round context: events recorded until
/// [`clear_round`] carry this semantic round number (the collectives'
/// loop index) instead of the ring sequence number. Compiled to a no-op
/// without the `obs` feature.
#[cfg(not(feature = "obs"))]
#[inline(always)]
pub fn set_round(_round: u64) {}

/// Clear the calling thread's round context (events fall back to the
/// ring sequence number). Compiled to a no-op without the `obs` feature.
#[cfg(feature = "obs")]
#[inline]
pub fn clear_round() {
    recorder::tls::clear_round();
}

/// Clear the calling thread's round context (events fall back to the
/// ring sequence number). Compiled to a no-op without the `obs` feature.
#[cfg(not(feature = "obs"))]
#[inline(always)]
pub fn clear_round() {}

/// Record one wall-clock round on the attached recorder (no-op when none
/// is attached): `send`/`recv` are `(peer, tag, bytes)` of the directions
/// that happened, `t0_ns` is the [`now_ns`] stamp taken before the
/// exchange; `t_end` is stamped here. The event's peer/block/bytes come
/// from the send direction when present (the rank's own outgoing edge),
/// else from the receive. Compiled to a no-op without the `obs` feature.
#[cfg(feature = "obs")]
#[inline]
pub fn record_round(send: Option<(u64, u64, u64)>, recv: Option<(u64, u64, u64)>, t0_ns: u64) {
    recorder::tls::record_round(send, recv, t0_ns);
}

/// Record one wall-clock round on the attached recorder (no-op when none
/// is attached): `send`/`recv` are `(peer, tag, bytes)` of the directions
/// that happened, `t0_ns` is the [`now_ns`] stamp taken before the
/// exchange; `t_end` is stamped here. The event's peer/block/bytes come
/// from the send direction when present (the rank's own outgoing edge),
/// else from the receive. Compiled to a no-op without the `obs` feature.
#[cfg(not(feature = "obs"))]
#[inline(always)]
pub fn record_round(
    _send: Option<(u64, u64, u64)>,
    _recv: Option<(u64, u64, u64)>,
    _t0_ns: u64,
) {
}

/// Record one *simulated-time* round on the attached recorder (cost
/// backend): timestamps are simulated seconds, converted to integer
/// nanoseconds. `dur_s` must be the recording rank's **own** edge cost so
/// calibration sees exact `α + β·bytes` samples (the global round time is
/// the max over edges and would mix block sizes). Compiled to a no-op
/// without the `obs` feature.
#[cfg(feature = "obs")]
#[inline]
pub fn record_sim(
    send: Option<(u64, u64, u64)>,
    recv: Option<(u64, u64, u64)>,
    t_start_s: f64,
    dur_s: f64,
) {
    recorder::tls::record_sim(send, recv, t_start_s, dur_s);
}

/// Record one *simulated-time* round on the attached recorder (cost
/// backend): timestamps are simulated seconds, converted to integer
/// nanoseconds. `dur_s` must be the recording rank's **own** edge cost so
/// calibration sees exact `α + β·bytes` samples (the global round time is
/// the max over edges and would mix block sizes). Compiled to a no-op
/// without the `obs` feature.
#[cfg(not(feature = "obs"))]
#[inline(always)]
pub fn record_sim(
    _send: Option<(u64, u64, u64)>,
    _recv: Option<(u64, u64, u64)>,
    _t_start_s: f64,
    _dur_s: f64,
) {
}
