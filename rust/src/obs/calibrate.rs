//! Measured α/β: least-squares calibration of the linear cost model
//! from recorded round samples.
//!
//! Every backend prices (or spends) `α + β·bytes` per block. Given the
//! recorder's `(bytes, duration)` pairs, ordinary least squares recovers
//! the two constants:
//!
//! ```text
//! β̂ = Σ(bᵢ - b̄)(tᵢ - t̄) / Σ(bᵢ - b̄)²        α̂ = t̄ - β̂·b̄
//! ```
//!
//! Zero-byte samples (idle rounds, barrier tokens) are excluded — they
//! spend no link time and would drag α̂ toward 0 — and the fit needs at
//! least two *distinct* block sizes or the slope is unidentifiable
//! (uniform blocks give `Σ(bᵢ - b̄)² = 0`; run with `Auto` segmentation
//! or an `m` not divisible by `n` so the capped final block varies the
//! size). The result converts to a
//! [`crate::transport::CostHint`], which
//! [`crate::transport::Transport::with_measured_hint`] feeds back into
//! `Algorithm::Auto` and the n* segmentation — measured constants in
//! place of static ones.

use super::recorder::{Recorder, RoundEvent, NO_PEER};
use crate::transport::CostHint;

/// A fitted linear cost model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Fit {
    /// Fitted per-message startup latency, seconds.
    pub alpha_s: f64,
    /// Fitted per-byte transfer time, seconds.
    pub beta_s_per_byte: f64,
    /// Number of non-empty samples behind the fit.
    pub samples: usize,
}

impl Fit {
    /// The fit as a [`CostHint`] for
    /// [`crate::transport::Transport::with_measured_hint`].
    pub fn hint(&self) -> CostHint {
        CostHint {
            alpha_s: self.alpha_s,
            beta_s_per_byte: self.beta_s_per_byte,
        }
    }
}

/// Least-squares fit over raw `(bytes, duration_s)` samples. Zero-byte
/// samples are skipped; `None` when fewer than two samples remain or all
/// remaining sizes are equal (slope unidentifiable).
pub fn fit_samples(samples: impl IntoIterator<Item = (u64, f64)>) -> Option<Fit> {
    let kept: Vec<(f64, f64)> = samples
        .into_iter()
        .filter(|&(bytes, _)| bytes > 0)
        .map(|(bytes, dur)| (bytes as f64, dur))
        .collect();
    if kept.len() < 2 {
        return None;
    }
    let n = kept.len() as f64;
    let mean_b = kept.iter().map(|&(b, _)| b).sum::<f64>() / n;
    let mean_t = kept.iter().map(|&(_, t)| t).sum::<f64>() / n;
    let sxx: f64 = kept.iter().map(|&(b, _)| (b - mean_b) * (b - mean_b)).sum();
    if sxx <= 0.0 {
        return None;
    }
    let sxy: f64 = kept
        .iter()
        .map(|&(b, t)| (b - mean_b) * (t - mean_t))
        .sum();
    let beta = sxy / sxx;
    Some(Fit {
        alpha_s: mean_t - beta * mean_b,
        beta_s_per_byte: beta,
        samples: kept.len(),
    })
}

/// Fit over recorded events (idle / peer-less events are skipped along
/// with zero-byte ones).
pub fn fit_events<'a>(events: impl IntoIterator<Item = &'a RoundEvent>) -> Option<Fit> {
    fit_samples(
        events
            .into_iter()
            .filter(|ev| ev.peer != NO_PEER)
            .map(|ev| (ev.bytes, ev.duration_ns() as f64 * 1e-9)),
    )
}

/// Fit over everything a recorder retained, all ranks pooled — the
/// per-backend calibration.
pub fn fit_recorder(rec: &Recorder) -> Option<Fit> {
    let all = rec.all_events();
    fit_events(all.iter().map(|(_, ev)| ev))
}

/// Per-class fits: `classify(rank, event)` buckets each event (`None`
/// drops it), and each bucket is fitted independently. The TCP backend's
/// per-link-class calibration — e.g. with [`ring_distance_class`] — and
/// anything finer (per-peer, per-NUMA-domain) both reduce to this.
pub fn fit_by_class(
    events: &[(u64, RoundEvent)],
    classify: impl Fn(u64, &RoundEvent) -> Option<u64>,
) -> Vec<(u64, Fit)> {
    let mut buckets: std::collections::BTreeMap<u64, Vec<(u64, f64)>> =
        std::collections::BTreeMap::new();
    for (rank, ev) in events {
        if let Some(class) = classify(*rank, ev) {
            buckets
                .entry(class)
                .or_default()
                .push((ev.bytes, ev.duration_ns() as f64 * 1e-9));
        }
    }
    buckets
        .into_iter()
        .filter_map(|(class, samples)| fit_samples(samples).map(|fit| (class, fit)))
        .collect()
}

/// The circulant link classifier: class `k` holds the events whose ring
/// distance `min(d, p - d)` to the peer falls in `[2ᵏ, 2ᵏ⁺¹)` — the
/// power-of-two neighborhoods the schedules actually use, a natural
/// link-class split for hierarchical TCP meshes.
pub fn ring_distance_class(p: u64) -> impl Fn(u64, &RoundEvent) -> Option<u64> {
    move |rank, ev| {
        if ev.peer == NO_PEER || ev.peer >= p || rank >= p {
            return None;
        }
        let d = (ev.peer + p - rank) % p;
        let d = d.min(p - d);
        if d == 0 {
            return None;
        }
        Some(63 - d.leading_zeros() as u64)
    }
}
