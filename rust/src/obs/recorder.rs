//! The round-event recorder: fixed-capacity per-rank ring buffers with a
//! lock-free, allocation-free write path.
//!
//! Layout: one [`Ring`] per rank, each a `Box<[RoundEvent]>` of
//! `capacity` slots plus an atomic head counter. A write claims the next
//! sequence number with a relaxed `fetch_add` and stores the event into
//! `slot[seq % capacity]` — newest events overwrite oldest once the ring
//! wraps, so a bounded recorder can watch an unbounded run and keep the
//! tail. The intended discipline is single-writer-per-rank (each rank's
//! own thread records its own events) with readers draining **after** the
//! SPMD harness has joined the rank threads; the join is what makes the
//! slot contents well-defined to the reader.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Sentinel peer for rounds with no counterpart (idle rounds).
pub const NO_PEER: u64 = u64::MAX;

/// Sentinel block index for rounds that carried no block (idle rounds;
/// also what the barrier's reserved `u64::MAX` tag maps to).
pub const NO_BLOCK: i64 = -1;

/// One recorded communication round of one rank.
///
/// On the wall-clock backends (thread, tcp) timestamps are nanoseconds
/// since the recorder's creation; on the cost backend they are simulated
/// seconds scaled to integer nanoseconds. Within a single recorder the
/// two never mix.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RoundEvent {
    /// Semantic round number (the collective's loop index when the round
    /// context was set, else the ring sequence number).
    pub round: u64,
    /// Peer rank: the destination when the rank sent, else the source;
    /// [`NO_PEER`] for idle rounds.
    pub peer: u64,
    /// Block index (the transport tag), [`NO_BLOCK`] when none.
    pub block: i64,
    /// Accounted payload bytes of the rank's own edge (send preferred).
    pub bytes: u64,
    /// Start-of-round timestamp, ns.
    pub t_start_ns: u64,
    /// End-of-round timestamp, ns.
    pub t_end_ns: u64,
}

impl RoundEvent {
    /// `t_end - t_start`, saturating.
    pub fn duration_ns(&self) -> u64 {
        self.t_end_ns.saturating_sub(self.t_start_ns)
    }
}

impl Default for RoundEvent {
    fn default() -> RoundEvent {
        RoundEvent {
            round: 0,
            peer: NO_PEER,
            block: NO_BLOCK,
            bytes: 0,
            t_start_ns: 0,
            t_end_ns: 0,
        }
    }
}

struct Ring {
    /// Total events ever recorded for this rank (monotonic; the write
    /// index is `head % capacity`).
    head: AtomicU64,
    slots: Box<[UnsafeCell<RoundEvent>]>,
}

// SAFETY: slots are plain-old-data written through `UnsafeCell` under the
// single-writer-per-rank discipline documented on the module; readers
// drain after the writer threads have been joined (the join provides the
// happens-before edge). A torn read is impossible to observe under that
// discipline; violating it is a logic error that can yield stale/mixed
// events but no memory unsafety beyond the documented contract.
unsafe impl Sync for Ring {}

pub(crate) struct Shared {
    epoch: Instant,
    cap: usize,
    rings: Vec<Ring>,
}

impl Shared {
    #[inline]
    pub(crate) fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    /// Ring sequence number the next event for `rank` will get.
    #[inline]
    pub(crate) fn seq(&self, rank: u64) -> u64 {
        match self.rings.get(rank as usize) {
            Some(r) => r.head.load(Ordering::Relaxed),
            None => 0,
        }
    }

    #[inline]
    pub(crate) fn push(&self, rank: u64, ev: RoundEvent) {
        let Some(ring) = self.rings.get(rank as usize) else {
            return;
        };
        let seq = ring.head.fetch_add(1, Ordering::Relaxed) as usize;
        let slot = &ring.slots[seq % self.cap];
        // SAFETY: see `unsafe impl Sync for Ring`.
        unsafe { *slot.get() = ev };
    }
}

/// A per-rank round-event recorder. Cheap to clone (an `Arc` handle);
/// clones record into the same rings.
///
/// Recording is lock-free and allocation-free; all storage is allocated
/// up front by [`Recorder::new`]. Attach to a rank thread with
/// [`crate::obs::attach`] so the instrumented transports feed it, or call
/// [`Recorder::record`] directly (works without the `obs` cargo feature —
/// only the transport hooks are feature-gated).
#[derive(Clone)]
pub struct Recorder {
    shared: Arc<Shared>,
}

impl Recorder {
    /// A recorder for ranks `0..p`, keeping the newest
    /// `capacity_per_rank` events per rank (clamped to at least 1).
    /// Allocates `p × capacity_per_rank` event slots up front.
    pub fn new(p: u64, capacity_per_rank: usize) -> Recorder {
        let cap = capacity_per_rank.max(1);
        let rings = (0..p)
            .map(|_| Ring {
                head: AtomicU64::new(0),
                slots: (0..cap)
                    .map(|_| UnsafeCell::new(RoundEvent::default()))
                    .collect(),
            })
            .collect();
        Recorder {
            shared: Arc::new(Shared {
                epoch: Instant::now(),
                cap,
                rings,
            }),
        }
    }

    /// A recorder that records nothing: zero rings, every operation an
    /// early return, and [`crate::obs::attach`]ing it detaches — the
    /// runtime off switch.
    pub fn disabled() -> Recorder {
        Recorder {
            shared: Arc::new(Shared {
                epoch: Instant::now(),
                cap: 0,
                rings: Vec::new(),
            }),
        }
    }

    /// Whether this recorder has any rings (false for
    /// [`Recorder::disabled`]).
    pub fn is_enabled(&self) -> bool {
        !self.shared.rings.is_empty()
    }

    /// Number of ranks this recorder covers.
    pub fn p(&self) -> u64 {
        self.shared.rings.len() as u64
    }

    /// Events retained per rank.
    pub fn capacity(&self) -> usize {
        self.shared.cap
    }

    /// Nanoseconds since this recorder was created — the timestamp base
    /// every wall-clock event uses.
    pub fn now_ns(&self) -> u64 {
        self.shared.now_ns()
    }

    /// Record one event for `rank` directly (out-of-range ranks are
    /// ignored). The direct path is always compiled, independent of the
    /// `obs` feature; it is the profiling harness's entry point.
    pub fn record(&self, rank: u64, ev: RoundEvent) {
        self.shared.push(rank, ev);
    }

    /// Total events ever recorded for `rank` (including any that the ring
    /// has since overwritten).
    pub fn recorded(&self, rank: u64) -> u64 {
        self.shared.seq(rank)
    }

    /// The retained events for `rank`, oldest first — the newest
    /// `min(recorded, capacity)` of them.
    pub fn events(&self, rank: u64) -> Vec<RoundEvent> {
        let Some(ring) = self.shared.rings.get(rank as usize) else {
            return Vec::new();
        };
        let head = ring.head.load(Ordering::Acquire) as usize;
        let kept = head.min(self.shared.cap);
        (head - kept..head)
            // SAFETY: see `unsafe impl Sync for Ring`.
            .map(|seq| unsafe { *ring.slots[seq % self.shared.cap].get() })
            .collect()
    }

    /// All retained events as `(rank, event)` pairs, rank-major and
    /// oldest-first within a rank — the shape the export and calibration
    /// helpers consume.
    pub fn all_events(&self) -> Vec<(u64, RoundEvent)> {
        let mut out = Vec::new();
        for rank in 0..self.p() {
            out.extend(self.events(rank).into_iter().map(|ev| (rank, ev)));
        }
        out
    }

    #[cfg(feature = "obs")]
    pub(crate) fn shared(&self) -> &Arc<Shared> {
        &self.shared
    }
}

impl std::fmt::Debug for Recorder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Recorder")
            .field("p", &self.p())
            .field("capacity", &self.shared.cap)
            .finish()
    }
}

/// The feature-gated thread-local hot path behind the hook functions in
/// [`crate::obs`].
#[cfg(feature = "obs")]
pub(crate) mod tls {
    use super::*;
    use std::cell::{Cell, RefCell};

    const NO_ROUND: u64 = u64::MAX;

    thread_local! {
        static ACTIVE: RefCell<Option<(Arc<Shared>, u64)>> = const { RefCell::new(None) };
        static ROUND: Cell<u64> = const { Cell::new(NO_ROUND) };
    }

    pub(crate) fn attach(rec: &Recorder, rank: u64) {
        ACTIVE.with(|a| {
            *a.borrow_mut() = if rec.is_enabled() {
                Some((rec.shared().clone(), rank))
            } else {
                None
            };
        });
        ROUND.with(|r| r.set(NO_ROUND));
    }

    pub(crate) fn detach() {
        ACTIVE.with(|a| *a.borrow_mut() = None);
        ROUND.with(|r| r.set(NO_ROUND));
    }

    #[inline]
    pub(crate) fn is_active() -> bool {
        ACTIVE.with(|a| a.borrow().is_some())
    }

    #[inline]
    pub(crate) fn now_ns() -> u64 {
        ACTIVE.with(|a| match a.borrow().as_ref() {
            Some((shared, _)) => shared.now_ns(),
            None => 0,
        })
    }

    #[inline]
    pub(crate) fn set_round(round: u64) {
        ROUND.with(|r| r.set(round));
    }

    #[inline]
    pub(crate) fn clear_round() {
        ROUND.with(|r| r.set(NO_ROUND));
    }

    /// Peer/block/bytes of the rank's own edge: the send direction when
    /// present, else the receive, else the idle sentinels.
    #[inline]
    fn own_edge(
        send: Option<(u64, u64, u64)>,
        recv: Option<(u64, u64, u64)>,
    ) -> (u64, i64, u64) {
        match (send, recv) {
            (Some((to, tag, bytes)), _) => (to, tag as i64, bytes),
            (None, Some((from, tag, bytes))) => (from, tag as i64, bytes),
            (None, None) => (NO_PEER, NO_BLOCK, 0),
        }
    }

    #[inline]
    pub(crate) fn record_round(
        send: Option<(u64, u64, u64)>,
        recv: Option<(u64, u64, u64)>,
        t0_ns: u64,
    ) {
        ACTIVE.with(|a| {
            let borrow = a.borrow();
            let Some((shared, rank)) = borrow.as_ref() else {
                return;
            };
            let t1 = shared.now_ns();
            let round = ROUND.with(|r| r.get());
            let round = if round == NO_ROUND {
                shared.seq(*rank)
            } else {
                round
            };
            let (peer, block, bytes) = own_edge(send, recv);
            shared.push(
                *rank,
                RoundEvent {
                    round,
                    peer,
                    block,
                    bytes,
                    t_start_ns: t0_ns,
                    t_end_ns: t1,
                },
            );
        });
    }

    #[inline]
    pub(crate) fn record_sim(
        send: Option<(u64, u64, u64)>,
        recv: Option<(u64, u64, u64)>,
        t_start_s: f64,
        dur_s: f64,
    ) {
        ACTIVE.with(|a| {
            let borrow = a.borrow();
            let Some((shared, rank)) = borrow.as_ref() else {
                return;
            };
            let round = ROUND.with(|r| r.get());
            let round = if round == NO_ROUND {
                shared.seq(*rank)
            } else {
                round
            };
            let (peer, block, bytes) = own_edge(send, recv);
            let t0 = (t_start_s * 1e9).round() as u64;
            let t1 = ((t_start_s + dur_s) * 1e9).round() as u64;
            shared.push(
                *rank,
                RoundEvent {
                    round,
                    peer,
                    block,
                    bytes,
                    t_start_ns: t0,
                    t_end_ns: t1.max(t0),
                },
            );
        });
    }
}
